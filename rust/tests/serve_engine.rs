//! Contract tests for the serving engine (`solvers::serve`):
//!
//! * **Coalescing is invisible in the bits** — requests of widths 1/3/7/33
//!   packed into one SoA mega-batch are each bit-identical to solving that
//!   request as its own batch over the same session noise, across engine
//!   thread/chunk settings — and the same holds for size-aware packing
//!   (skipped heads keep their bits AND their deadline), for sharded
//!   10⁵-lane mega-requests, for the priority lane, for LRU-evicted and
//!   rebuilt sessions, and for the f32 diagonal-noise market model.
//! * **Sessions are isolated** — a session's request stream depends only on
//!   its own seed and request counter, never on which other sessions share
//!   the engine or how requests interleave.
//! * **Quarantine is per request** — a fault-injected request (NaN initial
//!   state, or a panicking vector field) surfaces as that request's
//!   structured `SolveError` with request-relative coordinates, while every
//!   other request in the same mega-batch keeps its exact fault-free bits.
//! * **`BatchStepper::reinit` is exact** — a reused stepper re-initialised
//!   in place is bit-identical to a freshly constructed one, for every
//!   in-tree stepper.
//!
//! (The steady-state zero-allocation pin lives in `serve_zero_alloc.rs` —
//! its counting global allocator needs a binary to itself.)

use neuralsde::solvers::systems::{MarketModel, TanhDiagonalBatch};
use neuralsde::solvers::{
    integrate_batched, AdmitPolicy, BatchEulerMaruyama, BatchHeun, BatchMidpoint, BatchOptions,
    BatchReversibleHeun, BatchSde, BatchStepper, FaultCause, ServeConfig, ServeEngine,
    SessionNoise, StoredBatchNoise,
};

const T0: f64 = 0.0;
const T1: f64 = 1.0;
const N_STEPS: usize = 20;
const DIM: usize = 4;

fn sde() -> TanhDiagonalBatch {
    TanhDiagonalBatch::new(DIM, 1234)
}

fn y0_for(n_paths: usize, salt: usize) -> Vec<f64> {
    (0..DIM * n_paths)
        .map(|i| 0.05 * ((i + 3 * salt) % 11) as f64 - 0.2)
        .collect()
}

/// The per-request reference: rebuild the session's `k`-th request noise
/// with a replica `SessionNoise` and solve it as its own batch. This is
/// the ground truth the engine's coalesced answers must match bit-for-bit.
fn reference_request(seed: u64, request_idx: u64, n_paths: usize, y0: &[f64]) -> Vec<f64> {
    let mut sess = SessionNoise::new(seed, DIM, n_paths, T0, T1, N_STEPS);
    for _ in 0..request_idx {
        sess.next_request();
    }
    let grid = sess.next_request();
    let noise = StoredBatchNoise::<f64>::from_f32_grid(T0, T1, N_STEPS, DIM, n_paths, grid);
    let opts = BatchOptions { threads: 1, chunk: 7, ..Default::default() };
    integrate_batched::<BatchReversibleHeun, _, _>(
        &sde(),
        &noise,
        y0,
        n_paths,
        T0,
        T1,
        N_STEPS,
        &opts,
    )
    .expect("reference solve faulted")
}

#[test]
fn coalesced_mega_batch_matches_per_request_bitwise() {
    // Four sessions of widths 1, 3, 7, 33 — packed into ONE 44-lane
    // mega-batch (gated admission) — must each reproduce their own
    // per-request solve exactly, for several thread/chunk fan-outs
    // (including chunks that straddle request boundaries).
    let widths = [1usize, 3, 7, 33];
    for &(threads, chunk) in &[(1usize, 64usize), (2, 5), (4, 3)] {
        let mut cfg = ServeConfig::new(T0, T1, N_STEPS);
        cfg.max_batch = 64;
        cfg.threads = threads;
        cfg.chunk = chunk;
        cfg.auto_admit = false;
        let engine = ServeEngine::<BatchReversibleHeun, _>::new(sde(), cfg);
        let sessions: Vec<_> = widths
            .iter()
            .enumerate()
            .map(|(s, &w)| engine.open_session(100 + s as u64, w))
            .collect();
        let tickets: Vec<_> = sessions
            .iter()
            .zip(widths.iter())
            .enumerate()
            .map(|(s, (&sid, &w))| engine.submit(sid, &y0_for(w, s)))
            .collect();
        engine.flush(); // one admission round: all four requests coalesce
        for (s, (t, &w)) in tickets.into_iter().zip(widths.iter()).enumerate() {
            let got = engine.wait(t).expect("request faulted");
            let expect = reference_request(100 + s as u64, 0, w, &y0_for(w, s));
            assert_eq!(
                got, expect,
                "width-{w} request differs from its per-request solve \
                 (threads={threads}, chunk={chunk})"
            );
        }
    }
}

#[test]
fn session_noise_is_isolated_from_interleaving() {
    // Engine 1 interleaves sessions A and B; engine 2 serves A alone.
    // A's requests must be bit-identical in both — the session counter,
    // not global engine traffic, keys the noise.
    let width = 5usize;
    let y0a = y0_for(width, 0);
    let y0b = y0_for(width, 9);
    let mut cfg = ServeConfig::new(T0, T1, N_STEPS);
    cfg.max_batch = 32;
    cfg.threads = 2;
    cfg.chunk = 4;

    let mixed = ServeEngine::<BatchReversibleHeun, _>::new(sde(), cfg);
    let a = mixed.open_session(77, width);
    let b = mixed.open_session(99, width);
    let mut mixed_a = Vec::new();
    for round in 0..3 {
        let ta = mixed.submit(a, &y0a);
        let tb = mixed.submit(b, &y0b);
        mixed_a.push(mixed.wait(ta).expect("A faulted"));
        mixed
            .wait(tb)
            .unwrap_or_else(|_| panic!("B faulted in round {round}"));
    }
    drop(mixed);

    let solo = ServeEngine::<BatchReversibleHeun, _>::new(sde(), cfg);
    let a2 = solo.open_session(77, width);
    for (round, from_mixed) in mixed_a.iter().enumerate() {
        let t = solo.submit(a2, &y0a);
        let from_solo = solo.wait(t).expect("A faulted");
        assert_eq!(
            from_mixed, &from_solo,
            "session A round {round} depends on unrelated engine traffic"
        );
        // And both equal the offline per-request reconstruction.
        let expect = reference_request(77, round as u64, width, &y0a);
        assert_eq!(from_solo, expect, "round {round} differs from reference");
    }
}

#[test]
fn packed_admission_skips_blocked_head_and_preserves_bits() {
    // Three requests of widths 33 / 20 / 7 against a 40-lane batch. Under
    // Packed, round one holds the width-7 request (priority lane) plus the
    // width-33 head; the width-20 request does not fit, is skipped, and is
    // admitted first into round two — deadline preserved, bits identical.
    // Under Fifo the width-20 head blocks everything behind it (the
    // measurable baseline the packing policy beats).
    let widths = [33usize, 20, 7];
    let seeds = [300u64, 301, 302];
    let refs: Vec<Vec<f64>> = widths
        .iter()
        .enumerate()
        .map(|(s, &w)| reference_request(seeds[s], 0, w, &y0_for(w, s)))
        .collect();
    for policy in [AdmitPolicy::Packed, AdmitPolicy::Fifo] {
        let mut cfg = ServeConfig::new(T0, T1, N_STEPS);
        cfg.max_batch = 40;
        cfg.threads = 2;
        cfg.chunk = 6;
        cfg.auto_admit = false;
        cfg.policy = policy;
        let engine = ServeEngine::<BatchReversibleHeun, _>::new(sde(), cfg);
        let tickets: Vec<_> = widths
            .iter()
            .enumerate()
            .map(|(s, &w)| {
                let sid = engine.open_session(seeds[s], w);
                engine.submit(sid, &y0_for(w, s))
            })
            .collect();
        engine.flush(); // round one
        let got33 = engine.wait(tickets[0]).expect("width-33 request faulted");
        assert_eq!(got33, refs[0], "width-33 bits ({policy:?})");
        let mut out = Vec::new();
        assert!(
            engine.try_wait_into(tickets[1], &mut out).is_none(),
            "width-20 cannot fit round one ({policy:?})"
        );
        match policy {
            AdmitPolicy::Packed => {
                // The width-7 request bin-packed into round one.
                let got7 = engine.wait(tickets[2]).expect("width-7 request faulted");
                assert_eq!(got7, refs[2], "width-7 bits (packed)");
                engine.flush(); // round two: the skipped head goes first
                let got20 = engine.wait(tickets[1]).expect("width-20 request faulted");
                assert_eq!(got20, refs[1], "width-20 bits (packed)");
            }
            AdmitPolicy::Fifo => {
                // Strict order: width-7 is stuck behind the blocked head.
                assert!(
                    engine.try_wait_into(tickets[2], &mut out).is_none(),
                    "fifo must not skip ahead of the width-20 head"
                );
                engine.flush(); // round two: 20 + 7 together
                let got20 = engine.wait(tickets[1]).expect("width-20 request faulted");
                assert_eq!(got20, refs[1], "width-20 bits (fifo)");
                let got7 = engine.wait(tickets[2]).expect("width-7 request faulted");
                assert_eq!(got7, refs[2], "width-7 bits (fifo)");
            }
        }
    }
}

#[test]
fn sharded_mega_request_matches_unsharded_bitwise() {
    // A 10⁵-path request — far wider than the 4096-lane mega-batch — is
    // sharded across ~98 admission rounds of 1024 lanes and must reproduce
    // the unsharded single-batch solve exactly, across thread/chunk
    // fan-outs. (Wide sessions also exercise the blocked noise derivation:
    // NOISE_BLOCK-path Brownian blocks, bounded tree memory.)
    let dim = 2usize;
    let n_paths = 100_000usize;
    let n_steps = 6usize;
    let y0: Vec<f64> = (0..dim * n_paths).map(|i| 0.1 + ((i % 13) as f64) * 0.01).collect();
    let mut sess = SessionNoise::new(4242, dim, n_paths, T0, T1, n_steps);
    let grid = sess.next_request();
    let noise = StoredBatchNoise::<f64>::from_f32_grid(T0, T1, n_steps, dim, n_paths, grid);
    let opts = BatchOptions { threads: 4, chunk: 1024, ..Default::default() };
    let expect = integrate_batched::<BatchReversibleHeun, _, _>(
        &TanhDiagonalBatch::new(dim, 77),
        &noise,
        &y0,
        n_paths,
        T0,
        T1,
        n_steps,
        &opts,
    )
    .expect("unsharded reference faulted");
    for &(threads, chunk) in &[(2usize, 64usize), (4, 37)] {
        let mut cfg = ServeConfig::new(T0, T1, n_steps);
        cfg.max_batch = 4096;
        cfg.shard_width = 1024;
        cfg.threads = threads;
        cfg.chunk = chunk;
        let engine =
            ServeEngine::<BatchReversibleHeun, _>::new(TanhDiagonalBatch::new(dim, 77), cfg);
        let sid = engine.open_session(4242, n_paths);
        let t = engine.submit(sid, &y0);
        let got = engine.wait(t).expect("sharded mega-request faulted");
        assert_eq!(
            got, expect,
            "sharded solve differs from unsharded (threads={threads}, chunk={chunk})"
        );
    }
}

#[test]
fn priority_lane_completes_during_sharded_mega_request() {
    // A width-2 interactive request submitted AFTER a 200-path mega-request
    // completes in the mega's FIRST shard round (priority lane), while the
    // mega needs its full shard sequence — and both keep their exact bits.
    let mega_w = 200usize;
    let small_w = 2usize;
    let mut cfg = ServeConfig::new(T0, T1, N_STEPS);
    cfg.max_batch = 64;
    cfg.shard_width = 16;
    cfg.threads = 2;
    cfg.chunk = 8;
    cfg.auto_admit = false;
    let engine = ServeEngine::<BatchReversibleHeun, _>::new(sde(), cfg);
    let mega = engine.open_session(11, mega_w);
    let small = engine.open_session(22, small_w);
    let y0_mega = y0_for(mega_w, 1);
    let y0_small = y0_for(small_w, 2);
    let tm = engine.submit(mega, &y0_mega);
    let ts = engine.submit(small, &y0_small);
    engine.flush(); // one round: the small request + the mega's first shard
    let got_small = engine.wait(ts).expect("interactive request faulted");
    assert_eq!(
        got_small,
        reference_request(22, 0, small_w, &y0_small),
        "interactive bits under priority admission"
    );
    let mut out = Vec::new();
    assert!(
        engine.try_wait_into(tm, &mut out).is_none(),
        "the mega-request cannot be done after one 16-lane shard round"
    );
    // Drain the remaining shard rounds (gated mode: one flush per round;
    // extra flushes while a round is active are harmless).
    let mut done = None;
    for _ in 0..10_000 {
        engine.flush();
        std::thread::sleep(std::time::Duration::from_millis(1));
        if let Some(res) = engine.try_wait_into(tm, &mut out) {
            done = Some(res);
            break;
        }
    }
    done.expect("mega-request never completed").expect("mega-request faulted");
    assert_eq!(
        out,
        reference_request(11, 0, mega_w, &y0_mega),
        "sharded mega-request bits under priority interleaving"
    );
}

#[test]
fn session_eviction_rebuilds_bit_identically() {
    // Three sessions against a resident cap of two: every round evicts and
    // rebuilds somebody. The bits must be exactly the no-eviction reference
    // for every session and round, and the cap must hold.
    let width = 4usize;
    let mut cfg = ServeConfig::new(T0, T1, N_STEPS);
    cfg.max_batch = 16;
    cfg.threads = 2;
    cfg.chunk = 4;
    cfg.max_sessions = 2;
    let engine = ServeEngine::<BatchReversibleHeun, _>::new(sde(), cfg);
    let seeds = [800u64, 801, 802];
    let ids: Vec<_> = seeds.iter().map(|&s| engine.open_session(s, width)).collect();
    assert!(engine.resident_sessions() <= 2, "cap must hold after opens");
    for round in 0..3u64 {
        for (s, &sid) in ids.iter().enumerate() {
            let y0 = y0_for(width, s);
            let t = engine.submit(sid, &y0);
            let got = engine.wait(t).expect("request on an evicted session faulted");
            assert_eq!(
                got,
                reference_request(seeds[s], round, width, &y0),
                "session {s} round {round}: eviction changed the bits"
            );
            assert!(
                engine.resident_sessions() <= 2,
                "resident sessions exceeded the cap mid-traffic"
            );
        }
    }
}

/// Owned fault-injection wrapper (the engine takes its SDE by value, so the
/// borrowing `guard::PanicOnSentinel` doesn't fit): panics in `drift_batch`
/// whenever any state component equals the sentinel, exactly like its
/// borrowing counterpart.
struct PanickingTanh {
    inner: TanhDiagonalBatch,
    sentinel: f64,
}

impl BatchSde for PanickingTanh {
    fn state_dim(&self) -> usize {
        self.inner.state_dim()
    }
    fn brownian_dim(&self) -> usize {
        self.inner.brownian_dim()
    }
    fn diagonal_noise(&self) -> bool {
        self.inner.diagonal_noise()
    }
    fn drift_batch(&self, t: f64, y: &[f64], out: &mut [f64], batch: usize) {
        if y.iter().any(|&v| v == self.sentinel) {
            panic!("injected: sentinel state reached drift");
        }
        self.inner.drift_batch(t, y, out, batch);
    }
    fn diffusion_batch(&self, t: f64, y: &[f64], out: &mut [f64], batch: usize) {
        self.inner.diffusion_batch(t, y, out, batch);
    }
    fn diffusion_diag_batch(&self, t: f64, y: &[f64], out: &mut [f64], batch: usize) {
        self.inner.diffusion_diag_batch(t, y, out, batch);
    }
}

#[test]
fn faulted_request_is_quarantined_without_touching_others() {
    const SENTINEL: f64 = 1e30;
    let widths = [3usize, 4, 3];
    let mut cfg = ServeConfig::new(T0, T1, N_STEPS);
    cfg.max_batch = 16;
    cfg.threads = 2;
    cfg.chunk = 4; // chunks straddle request boundaries on purpose
    cfg.auto_admit = false;

    // Baseline: all three requests clean.
    let clean_engine = ServeEngine::<BatchReversibleHeun, _>::new(
        PanickingTanh { inner: sde(), sentinel: SENTINEL },
        cfg,
    );
    let clean_tickets: Vec<_> = widths
        .iter()
        .enumerate()
        .map(|(s, &w)| {
            let sid = clean_engine.open_session(500 + s as u64, w);
            clean_engine.submit(sid, &y0_for(w, s))
        })
        .collect();
    clean_engine.flush();
    let clean: Vec<_> = clean_tickets
        .into_iter()
        .map(|t| clean_engine.wait(t).expect("clean request faulted"))
        .collect();
    drop(clean_engine);

    // Same traffic, but request 1 carries the sentinel in path 2's first
    // component: its drift panics on step one.
    for inject_nan_instead in [false, true] {
        let engine = ServeEngine::<BatchReversibleHeun, _>::new(
            PanickingTanh { inner: sde(), sentinel: SENTINEL },
            cfg,
        );
        let mut tickets = Vec::new();
        for (s, &w) in widths.iter().enumerate() {
            let sid = engine.open_session(500 + s as u64, w);
            let mut y0 = y0_for(w, s);
            if s == 1 {
                // component 0 of path 2: SoA index 0 * w + 2
                y0[2] = if inject_nan_instead { f64::NAN } else { SENTINEL };
            }
            tickets.push(engine.submit(sid, &y0));
        }
        engine.flush();
        for (s, t) in tickets.into_iter().enumerate() {
            if s == 1 {
                let err = engine
                    .wait(t)
                    .expect_err("injected request must surface its fault");
                assert!(
                    err.faults.iter().any(|f| f.path == 2),
                    "fault must carry the request-relative path: {err}"
                );
                if inject_nan_instead {
                    assert!(
                        err.faults.iter().any(|f| f.cause == FaultCause::NonFinite),
                        "NaN y0 must localise as NonFinite: {err}"
                    );
                } else {
                    assert!(
                        err.faults
                            .iter()
                            .any(|f| matches!(&f.cause, FaultCause::VectorFieldPanic { payload }
                                if payload.contains("sentinel"))),
                        "sentinel must localise as VectorFieldPanic: {err}"
                    );
                }
            } else {
                let got = engine.wait(t).expect("bystander request faulted");
                assert_eq!(
                    got, clean[s],
                    "request {s} bits changed by another request's quarantine \
                     (nan={inject_nan_instead})"
                );
            }
        }
        // The engine stays serviceable: the quarantined slot was released
        // and a fresh, clean request on a new session round-trips.
        let sid = engine.open_session(909, 2);
        let t = engine.submit(sid, &y0_for(2, 7));
        engine.flush();
        engine.wait(t).expect("engine wedged after a quarantined request");
    }
}

#[test]
fn shard_fault_is_quarantined_to_the_owning_mega_request() {
    // A 150-path mega-request sharded into 64-lane rounds carries a
    // panicking sentinel at path 100 (inside its SECOND shard). The fault
    // must surface on the mega-request alone, with the request-relative
    // path coordinate, while a co-served bystander request and the engine
    // itself stay untouched.
    const SENTINEL: f64 = 1e30;
    let mega_w = 150usize;
    let by_w = 3usize;
    let mut cfg = ServeConfig::new(T0, T1, N_STEPS);
    cfg.max_batch = 64;
    cfg.shard_width = 64;
    cfg.threads = 2;
    cfg.chunk = 16;
    let engine = ServeEngine::<BatchReversibleHeun, _>::new(
        PanickingTanh { inner: sde(), sentinel: SENTINEL },
        cfg,
    );
    let mega = engine.open_session(600, mega_w);
    let by = engine.open_session(601, by_w);
    let mut y0m = y0_for(mega_w, 4);
    y0m[100] = SENTINEL; // component 0 of path 100
    let y0b = y0_for(by_w, 5);
    let tm = engine.submit(mega, &y0m);
    let tb = engine.submit(by, &y0b);
    let err = engine.wait(tm).expect_err("the injected shard must fault the mega-request");
    assert!(
        err.faults.iter().all(|f| f.path == 100),
        "faults must carry the request-relative path (100), got: {err}"
    );
    assert!(
        err.faults.iter().any(|f| matches!(&f.cause, FaultCause::VectorFieldPanic { payload }
            if payload.contains("sentinel"))),
        "sentinel must localise as VectorFieldPanic: {err}"
    );
    let got = engine.wait(tb).expect("bystander request faulted");
    assert_eq!(
        got,
        reference_request(601, 0, by_w, &y0b),
        "bystander bits changed by a sibling shard's quarantine"
    );
    // The engine stays serviceable after a quarantined shard.
    let t2 = engine.submit(by, &y0b);
    let got2 = engine.wait(t2).expect("engine wedged after a quarantined shard");
    assert_eq!(got2, reference_request(601, 1, by_w, &y0b));
}

#[test]
fn f32_market_model_diag_fast_path_matches_reference_bitwise() {
    // The serving fast path of the tentpole: the diagonal-noise market
    // model on the 8-wide f32 lanes, packed 1/3/7/33 into one mega-batch,
    // bit-identical per request to the solo f32 solve over the same noise.
    let d = 4usize;
    let widths = [1usize, 3, 7, 33];
    let mut cfg = ServeConfig::new(T0, T1, N_STEPS);
    cfg.max_batch = 64;
    cfg.threads = 2;
    cfg.chunk = 5;
    cfg.auto_admit = false;
    let engine =
        ServeEngine::<BatchReversibleHeun<f32>, _>::new(MarketModel::new(d, 31), cfg);
    let tickets: Vec<(neuralsde::solvers::Ticket, usize, u64, Vec<f32>)> = widths
        .iter()
        .enumerate()
        .map(|(s, &w)| {
            let seed = 900 + s as u64;
            let y0: Vec<f32> = (0..d * w).map(|i| 1.0 + 0.01 * ((i + s) % 7) as f32).collect();
            let sid = engine.open_session(seed, w);
            (engine.submit(sid, &y0), w, seed, y0)
        })
        .collect();
    engine.flush();
    for (t, w, seed, y0) in tickets {
        let got = engine.wait(t).expect("market-model request faulted");
        let mut sess = SessionNoise::new(seed, d, w, T0, T1, N_STEPS);
        let grid = sess.next_request();
        let noise = StoredBatchNoise::<f32>::from_f32_grid(T0, T1, N_STEPS, d, w, grid);
        let opts = BatchOptions { threads: 1, chunk: 7, ..Default::default() };
        let expect = integrate_batched::<BatchReversibleHeun<f32>, _, _>(
            &MarketModel::new(d, 31),
            &noise,
            &y0,
            w,
            T0,
            T1,
            N_STEPS,
            &opts,
        )
        .expect("f32 reference solve faulted");
        assert_eq!(got, expect, "width-{w} f32 market-model request differs from solo");
    }
}

/// `reinit` on a warmed stepper must be bit-identical to a fresh
/// `for_chunk` — including at a smaller batch than the stepper was warmed
/// at (the serving engine's remainder-chunk shape).
fn reinit_matches_fresh<M: BatchStepper<Elem = f64>>() {
    let sys = sde();
    let warm_batch = 8usize;
    let run_batch = 5usize;
    let y0 = y0_for(run_batch, 3);
    let dw: Vec<f64> = (0..DIM * run_batch).map(|i| 0.01 * (i as f64 - 7.0)).collect();
    let dt = (T1 - T0) / N_STEPS as f64;

    // Warm at a larger batch, then reinit down to the run shape.
    let warm_y0 = vec![0.0f64; DIM * warm_batch];
    let mut reused = M::for_chunk(&sys, T0, &warm_y0, warm_batch);
    reused.reinit(&sys, T0, &y0, run_batch);
    let mut fresh = M::for_chunk(&sys, T0, &y0, run_batch);

    let mut y_reused = y0.clone();
    let mut y_fresh = y0.clone();
    for k in 0..6 {
        let s = T0 + k as f64 * dt;
        reused.step(&sys, s, dt, &dw, &mut y_reused, run_batch);
        fresh.step(&sys, s, dt, &dw, &mut y_fresh, run_batch);
        assert_eq!(y_reused, y_fresh, "step {k}: reinit diverged from for_chunk");
    }
}

#[test]
fn reinit_is_bit_identical_for_every_stepper() {
    reinit_matches_fresh::<BatchEulerMaruyama>();
    reinit_matches_fresh::<BatchMidpoint>();
    reinit_matches_fresh::<BatchHeun>();
    reinit_matches_fresh::<BatchReversibleHeun>();
}
