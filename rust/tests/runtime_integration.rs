//! Integration tests over the PJRT runtime + AOT artifacts.
//!
//! These require `make artifacts`; when the artifact directory is absent
//! (e.g. a fresh checkout before the build step) they skip rather than
//! fail, so `cargo test` stays green in every state of the pipeline. The
//! GAN executable tests additionally need the `pjrt` feature, since the
//! trainer's runtime methods live behind it (the native GAN path is covered
//! by `tests/neural_gan.rs` without any artifacts).

use neuralsde::brownian::SplitPrng;
use neuralsde::config::TrainConfig;
use neuralsde::coordinator::{gradient_error, LatentTrainer};
#[cfg(feature = "pjrt")]
use neuralsde::coordinator::GanTrainer;
use neuralsde::data::air;
#[cfg(feature = "pjrt")]
use neuralsde::data::ou;
use neuralsde::runtime::{load_runtime, Runtime};

fn runtime() -> Option<neuralsde::runtime::Runtime> {
    if !Runtime::artifacts_present("artifacts") {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return None;
    }
    Some(load_runtime("artifacts").expect("runtime should load"))
}

#[test]
fn manifest_lists_expected_executables() {
    let Some(rt) = runtime() else { return };
    for name in [
        "gan_ou_reversible_heun_gen_grad",
        "gan_ou_reversible_heun_disc_grad",
        "gan_ou_reversible_heun_sample",
        "gan_ou_midpoint_gen_grad",
        "gan_ou_midpoint_disc_grad_gp",
        "latent_air_reversible_heun_grad",
        "graderr_reversible_heun_n16",
        "graderr_midpoint_n16",
        "graderr_heun_n16",
    ] {
        assert!(
            rt.manifest.execs.contains_key(name),
            "manifest missing {name}"
        );
    }
    // Layout/hyper contract.
    let m = rt.manifest.model("gan_ou").expect("gan_ou model");
    assert!(m.gen_layout.total > 0);
    assert!(m.disc_layout.total > 0);
    assert_eq!(rt.manifest.hyper("gan_ou", "seq_len").unwrap(), 32.0);
}

#[cfg(feature = "pjrt")]
#[test]
fn gan_training_step_runs_and_updates_params() {
    let Some(mut rt) = runtime() else { return };
    let cfg = TrainConfig::default();
    let mut data = ou::generate(64, 3, ou::OuParams::default());
    data.normalise_initial();
    let mut trainer = GanTrainer::from_runtime(&rt, &cfg, 4).expect("trainer");
    let theta0 = trainer.theta.clone();
    let phi0 = trainer.phi.clone();
    let mut rng = SplitPrng::new(1);
    let stats = trainer.train_step_runtime(&mut rt, &data, &mut rng).expect("step");
    assert!(stats.loss_g.is_finite());
    assert!(stats.loss_d.is_finite());
    assert_ne!(trainer.theta, theta0, "generator params should move");
    assert_ne!(trainer.phi, phi0, "discriminator params should move");
    // Clipping invariant: every f./g. weight is inside [-1/fan_in, 1/fan_in].
    let dl = rt.manifest.model("gan_ou").unwrap().disc_layout.clone();
    for t in &dl.tensors {
        if t.kind == neuralsde::nn::ParamKind::Weight
            && (t.name.starts_with("f.") || t.name.starts_with("g."))
        {
            let bound = 1.0 / t.fan_in as f32 + 1e-6;
            for &v in &trainer.phi[t.offset..t.offset + t.len()] {
                assert!(v.abs() <= bound, "{}: {v} beyond {bound}", t.name);
            }
        }
    }
}

#[cfg(feature = "pjrt")]
#[test]
fn gan_sampling_produces_finite_series() {
    let Some(mut rt) = runtime() else { return };
    let cfg = TrainConfig::default();
    let mut trainer = GanTrainer::from_runtime(&rt, &cfg, 1).expect("trainer");
    let fake = trainer.sample_runtime(&mut rt, 32).expect("sample");
    assert_eq!(fake.n, 32);
    assert_eq!(fake.seq_len, 32);
    assert!(fake.values.iter().all(|v| v.is_finite()));
    // Not all-zero / not constant.
    let spread = fake.values.iter().cloned().fold(f32::MIN, f32::max)
        - fake.values.iter().cloned().fold(f32::MAX, f32::min);
    assert!(spread > 1e-3, "degenerate samples, spread {spread}");
}

#[test]
fn latent_training_step_runs() {
    let Some(mut rt) = runtime() else { return };
    let mut cfg = TrainConfig::default();
    cfg.dataset = neuralsde::config::DatasetKind::Air;
    let mut data = air::generate(64, 3, air::AirParams::default());
    data.normalise_initial();
    let mut trainer = LatentTrainer::new(&rt, &cfg).expect("trainer");
    let mut rng = SplitPrng::new(1);
    let l1 = trainer.train_step(&mut rt, &data, &mut rng).expect("step");
    assert!(l1.is_finite());
}

#[test]
fn gradient_error_revheun_is_fp_exact_midpoint_is_not() {
    let Some(mut rt) = runtime() else { return };
    let points = gradient_error::run(&mut rt, 7).expect("graderr");
    assert!(!points.is_empty());
    for p in &points {
        if p.solver == "reversible_heun" {
            assert!(p.rel_err < 1e-10, "revheun n={}: {}", p.n_steps, p.rel_err);
        } else if p.n_steps <= 16 {
            assert!(p.rel_err > 1e-8, "{} n={}: {}", p.solver, p.n_steps, p.rel_err);
        }
    }
}

#[cfg(feature = "pjrt")]
#[test]
fn determinism_same_seed_same_losses() {
    let Some(mut rt) = runtime() else { return };
    let cfg = TrainConfig::default();
    let mut data = ou::generate(64, 3, ou::OuParams::default());
    data.normalise_initial();
    let mut run = |rt: &mut neuralsde::runtime::Runtime| {
        let mut tr = GanTrainer::from_runtime(rt, &cfg, 2).expect("trainer");
        let mut rng = SplitPrng::new(5);
        let s = tr.train_step_runtime(rt, &data, &mut rng).expect("step");
        (s.loss_g, s.loss_d)
    };
    let a = run(&mut rt);
    let b = run(&mut rt);
    assert_eq!(a, b, "training must be bit-deterministic given the seed");
}
