//! Measured solver-correctness properties, locked in as tests:
//!
//! * **strong convergence orders against analytic solutions** — Euler–
//!   Maruyama converges at order 0.5 on multiplicative noise, midpoint and
//!   Heun at order 1.0 on diagonal (here scalar, hence commutative) noise,
//!   both measured against the closed-form solution of the linear SDE
//!   `dy = a y dt + b y dW`; the reversible Heun method is measured on the
//!   analytic time-dependent Ornstein–Uhlenbeck system of Appendix F.7,
//!   whose solution is known in closed form given the Brownian path;
//! * **algebraic reversibility** — the batched reversible Heun round-trips
//!   forward∘reverse to `< 1e-10` across state dimensions, batch sizes and
//!   step counts (the property the paper's exact-gradient claim rests on);
//! * **the `f32` solve path keeps both properties** — strong orders measured
//!   on the 8-wide `f32` lanes match the theory with loosened windows (the
//!   single-precision roundoff floor sits well below the discretisation
//!   error at these step sizes), and the `f32` reversible Heun round-trips
//!   to single-precision roundoff.
//!
//! Orders are measured: solve many paths at several step sizes on a shared
//! fine Brownian grid, fit `log2(error)` against `log2(h)`, and pin the
//! fitted slope to a window around the theoretical order.

use neuralsde::brownian::SplitPrng;
use neuralsde::solvers::systems::{ScalarLinear, TanhDiagonal, TanhDiagonalBatch, TimeDependentOu};
use neuralsde::solvers::{
    aos_to_soa, integrate_batched, BatchEulerMaruyama, BatchHeun, BatchMidpoint, BatchNoise,
    BatchOptions, BatchReversibleHeun, BatchSde, BatchStepper, CounterGridNoise, EulerMaruyama,
    FixedStepSolver, Heun, Lane, Midpoint, ReversibleHeun, Sde, StoredBatchNoise,
};
use neuralsde::util::stats::linear_fit;

/// Fine Brownian increments for one path: `n_fine` iid `N(0, T/n_fine)`.
fn fine_increments(n_fine: usize, t1: f64, seed: u64) -> Vec<f64> {
    let sd = (t1 / n_fine as f64).sqrt();
    let mut rng = SplitPrng::new(seed);
    (0..n_fine).map(|_| rng.next_normal_pair().0 * sd).collect()
}

/// Sum consecutive blocks of the fine increments down to `n` coarse steps.
fn coarsen(fine: &[f64], n: usize) -> Vec<f64> {
    let block = fine.len() / n;
    assert_eq!(block * n, fine.len(), "coarse steps must divide the fine grid");
    (0..n).map(|k| fine[k * block..(k + 1) * block].iter().sum()).collect()
}

/// Integrate a 1-dim SDE over `[0, 1]` with the given per-step increments,
/// returning the terminal value.
fn terminal_1d<S: Sde, M: FixedStepSolver>(sde: &S, solver: &mut M, dws: &[f64], y0: f64) -> f64 {
    let n = dws.len();
    let dt = 1.0 / n as f64;
    let mut y = [y0];
    for (k, &dw) in dws.iter().enumerate() {
        solver.step(sde, k as f64 * dt, dt, &[dw], &mut y);
    }
    y[0]
}

/// Fit the strong-order slope from `(h, mean abs error)` pairs.
fn fitted_order(points: &[(f64, f64)]) -> f64 {
    let xs: Vec<f64> = points.iter().map(|p| p.0.log2()).collect();
    let ys: Vec<f64> = points.iter().map(|p| p.1.log2()).collect();
    let (_, slope) = linear_fit(&xs, &ys);
    slope
}

const STEP_COUNTS: [usize; 5] = [16, 32, 64, 128, 256];
const N_FINE: usize = 256;
const N_PATHS: usize = 400;

/// Mean terminal error per step count for a solver on [`ScalarLinear`],
/// against `exact(W_T)` — the caller picks the Itô or Stratonovich form.
fn scalar_linear_errors<M, MkM, Ex>(sde: &ScalarLinear, mk: MkM, exact: Ex) -> Vec<(f64, f64)>
where
    M: FixedStepSolver,
    MkM: Fn(&ScalarLinear) -> M,
    Ex: Fn(f64) -> f64,
{
    let mut errs = vec![0.0f64; STEP_COUNTS.len()];
    for p in 0..N_PATHS {
        let fine = fine_increments(N_FINE, 1.0, 1000 + p as u64);
        let w_total: f64 = fine.iter().sum();
        let truth = exact(w_total);
        for (i, &n) in STEP_COUNTS.iter().enumerate() {
            let dws = coarsen(&fine, n);
            let mut solver = mk(sde);
            let y = terminal_1d(sde, &mut solver, &dws, 1.0);
            errs[i] += (y - truth).abs();
        }
    }
    STEP_COUNTS
        .iter()
        .zip(errs)
        .map(|(&n, e)| (1.0 / n as f64, e / N_PATHS as f64))
        .collect()
}

#[test]
fn euler_maruyama_strong_order_half_multiplicative_noise() {
    // Itô linear SDE: exact solution y0 exp((a - b²/2) T + b W_T).
    let sde = ScalarLinear { a: 0.3, b: 0.5 };
    let pts = scalar_linear_errors(
        &sde,
        |_| EulerMaruyama::new(1, 1),
        |w| ((0.3 - 0.5 * 0.5 * 0.5) + 0.5 * w).exp(),
    );
    let order = fitted_order(&pts);
    assert!(
        order > 0.3 && order < 0.72,
        "Euler–Maruyama strong order {order}, errors {pts:?}"
    );
}

#[test]
fn midpoint_strong_order_one_diagonal_noise() {
    // Stratonovich linear SDE: exact solution y0 exp(a T + b W_T).
    let sde = ScalarLinear { a: 0.3, b: 0.5 };
    let pts = scalar_linear_errors(&sde, |_| Midpoint::new(1, 1), |w| (0.3 + 0.5 * w).exp());
    let order = fitted_order(&pts);
    assert!(
        order > 0.72 && order < 1.35,
        "midpoint strong order {order}, errors {pts:?}"
    );
}

#[test]
fn heun_strong_order_one_diagonal_noise() {
    let sde = ScalarLinear { a: 0.3, b: 0.5 };
    let pts = scalar_linear_errors(&sde, |_| Heun::new(1, 1), |w| (0.3 + 0.5 * w).exp());
    let order = fitted_order(&pts);
    assert!(
        order > 0.72 && order < 1.35,
        "Heun strong order {order}, errors {pts:?}"
    );
}

#[test]
fn reversible_heun_converges_on_analytic_ou() {
    // Time-dependent OU (Appendix F.7): dY = (ρt − κY) dt + χ dW, additive
    // noise. Conditioned on the Brownian path, the solution is exact per
    // step: Y_{t+h} = e^{-κh} Y_t + ρ ∫ s e^{-κ(t+h-s)} ds
    //                + χ ∫ e^{-κ(t+h-s)} dW_s,
    // with the deterministic integral in closed form and the stochastic
    // integral evaluated on a fine grid (conditional mean given each fine
    // increment), so the reference error is O(h_fine) with a tiny constant.
    let sde = TimeDependentOu::default();
    let (rho, kappa, chi) = (sde.rho, sde.kappa, sde.chi);
    let steps = [8usize, 16, 32, 64];
    let n_fine = 4096usize;
    let n_paths = 300usize;
    let hf = 1.0 / n_fine as f64;
    let ekh = (-kappa * hf).exp();
    let lam = (1.0 - ekh) / (kappa * hf); // E[∫ e^{-κ(t+h-s)} dW | ΔW] / ΔW
    let mut errs = vec![0.0f64; steps.len()];
    for p in 0..n_paths {
        let fine = fine_increments(n_fine, 1.0, 5000 + p as u64);
        // Exact solution on the fine grid.
        let mut y_ref = 1.0f64;
        for (j, &dw) in fine.iter().enumerate() {
            let t = j as f64 * hf;
            let det = rho
                * (t * (1.0 - ekh) / kappa + hf / kappa - (1.0 - ekh) / (kappa * kappa));
            y_ref = ekh * y_ref + det + chi * lam * dw;
        }
        for (i, &n) in steps.iter().enumerate() {
            let dws = coarsen(&fine, n);
            let mut solver = ReversibleHeun::new(&sde, 0.0, &[1.0]);
            let y = terminal_1d(&sde, &mut solver, &dws, 1.0);
            errs[i] += (y - y_ref).abs();
        }
    }
    let pts: Vec<(f64, f64)> = steps
        .iter()
        .zip(&errs)
        .map(|(&n, &e)| (1.0 / n as f64, e / n_paths as f64))
        .collect();
    for w in pts.windows(2) {
        assert!(
            w[1].1 < w[0].1,
            "error did not decrease with h: {pts:?}"
        );
    }
    let order = fitted_order(&pts);
    assert!(
        order > 0.7 && order < 2.5,
        "reversible Heun measured order {order} on the OU system, errors {pts:?}"
    );
}

// ---------------------------------------------------------------------------
// f32 / 8-wide lane path.
// ---------------------------------------------------------------------------

/// The linear Stratonovich/Itô test SDE as a precision-generic native batch
/// system (`dy = a y dt + b y dW` at the lane precision).
struct LinBatchGeneric {
    a: f64,
    b: f64,
}

impl<T: Lane> BatchSde<T> for LinBatchGeneric {
    fn state_dim(&self) -> usize {
        1
    }
    fn brownian_dim(&self) -> usize {
        1
    }
    fn diagonal_noise(&self) -> bool {
        true
    }
    fn drift_batch(&self, _t: f64, y: &[T], out: &mut [T], batch: usize) {
        let a = T::from_f64(self.a);
        for p in 0..batch {
            out[p] = a * y[p];
        }
    }
    fn diffusion_batch(&self, _t: f64, y: &[T], out: &mut [T], batch: usize) {
        let b = T::from_f64(self.b);
        for p in 0..batch {
            out[p] = b * y[p];
        }
    }
    fn diffusion_diag_batch(&self, _t: f64, y: &[T], out: &mut [T], batch: usize) {
        let b = T::from_f64(self.b);
        for p in 0..batch {
            out[p] = b * y[p];
        }
    }
}

/// Step counts for the f32 order fits: capped at 128 so the discretisation
/// error stays well above the single-precision roundoff floor.
const STEP_COUNTS_F32: [usize; 4] = [16, 32, 64, 128];
const N_PATHS_F32: usize = 256;

/// Mean f32 terminal error per step count on [`LinBatchGeneric`]: all paths
/// are solved in one 8-wide batched call per step count, driven by the
/// coarsened fine-grid increments stored as `f32`, and compared to the f64
/// closed form of the shared Brownian path.
fn f32_linear_errors<M, Ex>(sde: &LinBatchGeneric, exact: Ex) -> Vec<(f64, f64)>
where
    M: BatchStepper<Elem = f32>,
    Ex: Fn(f64) -> f64,
{
    let opts = BatchOptions { threads: 1, chunk: 64, ..Default::default() };
    let mut pts = Vec::with_capacity(STEP_COUNTS_F32.len());
    // Shared per-path fine grids (and their f64 totals for the truth).
    let fines: Vec<Vec<f64>> =
        (0..N_PATHS_F32).map(|p| fine_increments(N_FINE, 1.0, 1000 + p as u64)).collect();
    for &n in &STEP_COUNTS_F32 {
        let mut noise: StoredBatchNoise<f32> = StoredBatchNoise::zeros(0.0, 1.0, n, 1, N_PATHS_F32);
        for (p, fine) in fines.iter().enumerate() {
            for (k, dw) in coarsen(fine, n).iter().enumerate() {
                noise.set(k, 0, p, *dw as f32);
            }
        }
        let y0 = vec![1.0f32; N_PATHS_F32];
        let traj = integrate_batched::<M, _, _>(sde, &noise, &y0, N_PATHS_F32, 0.0, 1.0, n, &opts)
            .expect("fault-free by construction"); // test-only unwrap: no injection here
        let mut err = 0.0f64;
        for (p, fine) in fines.iter().enumerate() {
            let truth = exact(fine.iter().sum());
            err += (traj[n * N_PATHS_F32 + p] as f64 - truth).abs();
        }
        pts.push((1.0 / n as f64, err / N_PATHS_F32 as f64));
    }
    pts
}

#[test]
fn f32_euler_maruyama_strong_order_half() {
    // Itô linear SDE on 8-wide f32 lanes: same theory, loosened window.
    let sde = LinBatchGeneric { a: 0.3, b: 0.5 };
    let pts = f32_linear_errors::<BatchEulerMaruyama<f32>, _>(&sde, |w| {
        ((0.3 - 0.5 * 0.5 * 0.5) + 0.5 * w).exp()
    });
    let order = fitted_order(&pts);
    assert!(
        order > 0.25 && order < 0.8,
        "f32 Euler–Maruyama strong order {order}, errors {pts:?}"
    );
}

#[test]
fn f32_midpoint_strong_order_one() {
    let sde = LinBatchGeneric { a: 0.3, b: 0.5 };
    let pts = f32_linear_errors::<BatchMidpoint<f32>, _>(&sde, |w| (0.3 + 0.5 * w).exp());
    let order = fitted_order(&pts);
    assert!(
        order > 0.6 && order < 1.45,
        "f32 midpoint strong order {order}, errors {pts:?}"
    );
}

#[test]
fn f32_heun_strong_order_one() {
    let sde = LinBatchGeneric { a: 0.3, b: 0.5 };
    let pts = f32_linear_errors::<BatchHeun<f32>, _>(&sde, |w| (0.3 + 0.5 * w).exp());
    let order = fitted_order(&pts);
    assert!(
        order > 0.6 && order < 1.45,
        "f32 Heun strong order {order}, errors {pts:?}"
    );
}

#[test]
fn f32_batched_revheun_roundtrip_to_single_precision_roundoff() {
    // Forward n steps then reverse n steps recovers the initial (z, ẑ, μ, σ)
    // to single-precision roundoff — the f64 suite pins the same sweep at
    // 1e-10; the bound here is that pin loosened by the f32/f64 eps ratio
    // (state scale ~0.1, so 5e-3 is still ~20× below breakage).
    let (dim, batch, n) = (4usize, 8usize, 32usize);
    let sde = TanhDiagonalBatch::new(dim, 23);
    let aos: Vec<f32> = (0..batch * dim).map(|x| 0.03 * (x % 11) as f32 - 0.15).collect();
    let y0 = aos_to_soa(&aos, dim, batch);
    let noise = CounterGridNoise::new(7, dim, 0.0, 1.0, n);
    let dt = 1.0 / n as f64;
    let mut stepper = <BatchReversibleHeun<f32> as BatchStepper>::for_chunk(&sde, 0.0, &y0, batch);
    let (z0, zh0, mu0, sigma0) = (
        stepper.z().to_vec(),
        stepper.zh().to_vec(),
        stepper.mu().to_vec(),
        stepper.sigma().to_vec(),
    );
    let mut dws: Vec<Vec<f32>> = Vec::with_capacity(n);
    for k in 0..n {
        let (s, t) = (k as f64 * dt, (k + 1) as f64 * dt);
        let mut dw = vec![0.0f32; dim * batch];
        noise.fill_step(k, s, t, 0, batch, &mut dw);
        stepper.forward_step(&sde, s, dt, &dw);
        dws.push(dw);
    }
    for k in (0..n).rev() {
        stepper.reverse_step(&sde, (k + 1) as f64 * dt, dt, &dws[k]);
    }
    let max_diff = |a: &[f32], b: &[f32]| {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max)
    };
    let err = max_diff(stepper.z(), &z0)
        .max(max_diff(stepper.zh(), &zh0))
        .max(max_diff(stepper.mu(), &mu0))
        .max(max_diff(stepper.sigma(), &sigma0));
    assert!(err < 5e-3, "f32 forward∘reverse round-trip error {err}");
}

#[test]
fn batched_revheun_roundtrip_across_dims_batches_steps() {
    for &dim in &[1usize, 4, 10] {
        for &batch in &[1usize, 7, 32] {
            for &n in &[16usize, 100] {
                let sde = TanhDiagonal::new(dim, 3 * dim as u64 + batch as u64);
                let aos: Vec<f64> =
                    (0..batch * dim).map(|x| 0.03 * (x % 11) as f64 - 0.15).collect();
                let y0 = aos_to_soa(&aos, dim, batch);
                let noise = CounterGridNoise::new(7, dim, 0.0, 1.0, n);
                let dt = 1.0 / n as f64;
                let mut stepper = BatchReversibleHeun::for_chunk(&sde, 0.0, &y0, batch);
                let (z0, zh0, mu0, sigma0) = (
                    stepper.z().to_vec(),
                    stepper.zh().to_vec(),
                    stepper.mu().to_vec(),
                    stepper.sigma().to_vec(),
                );
                let mut dws: Vec<Vec<f64>> = Vec::with_capacity(n);
                for k in 0..n {
                    let (s, t) = (k as f64 * dt, (k + 1) as f64 * dt);
                    let mut dw = vec![0.0; dim * batch];
                    noise.fill_step(k, s, t, 0, batch, &mut dw);
                    stepper.forward_step(&sde, s, dt, &dw);
                    dws.push(dw);
                }
                for k in (0..n).rev() {
                    stepper.reverse_step(&sde, (k + 1) as f64 * dt, dt, &dws[k]);
                }
                let max_diff = |a: &[f64], b: &[f64]| {
                    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0f64, f64::max)
                };
                let err = max_diff(stepper.z(), &z0)
                    .max(max_diff(stepper.zh(), &zh0))
                    .max(max_diff(stepper.mu(), &mu0))
                    .max(max_diff(stepper.sigma(), &sigma0));
                assert!(
                    err < 1e-10,
                    "round-trip error {err} at dim={dim} batch={batch} n={n}"
                );
            }
        }
    }
}
