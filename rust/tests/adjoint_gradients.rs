//! Property tests for the native reverse-mode adjoint engine:
//!
//! * adjoint gradients agree with central finite differences of the same
//!   discrete solve on identical noise — across dims × batch sizes × step
//!   counts, and to ≤1e-6 relative L1 on the OU test problem;
//! * the batched-SoA adjoint is **bit-identical** to the per-path adjoint
//!   across the SIMD remainder batch sizes 1/3/4/7/8/33 (same lane pinning
//!   as the forward engine), for both backward modes, and invariant under
//!   chunk size and thread count;
//! * the native batched VJPs agree bit-for-bit with the blanket
//!   gather/scatter adapter;
//! * machine-precision round-trip on the closed-form OU problem: the
//!   O(1)-memory reconstruction gradient matches the exact 2×2 product
//!   Jacobian and the stored-tape gradient to <1e-10;
//! * every `SdeVjp` impl passes the central-difference harness at several
//!   step sizes (truncation-dominated and roundoff-dominated regimes);
//! * Brownian-Interval-backed backward replay (`fill_grid` once, consume in
//!   reverse) is bit-identical to per-step interval queries;
//! * the flat native gradient drives `nn::optim` end to end (loss descends).

use neuralsde::brownian::BrownianInterval;
use neuralsde::coordinator::gradient_error::relative_l1;
use neuralsde::nn::{step_f64, Adam};
use neuralsde::solvers::systems::{
    Anharmonic, DenseCoupled, DenseCoupledBatch, ScalarLinear, TanhDiagonal, TanhDiagonalBatch,
    TimeDependentOu,
};
use neuralsde::solvers::{
    adjoint_solve, adjoint_solve_batched, aos_to_soa, integrate, max_vjp_fd_error, AdjointGrad,
    BackwardMode, BatchOptions, CounterGridNoise, GridReplayNoise, NoiseFromSource,
    ReversibleHeun, Sde,
};
use neuralsde::util::stats::central_gradient;

/// Per-path starting states, slightly different per path so lane mixups
/// would be caught.
fn aos_start(dim: usize, batch: usize) -> Vec<f64> {
    (0..batch * dim).map(|x| 0.02 * (x % 17) as f64 - 0.1).collect()
}

/// Component-varying terminal cotangent (catches transposed lanes).
fn seed_per_path(gz: &mut [f64]) {
    for (i, g) in gz.iter_mut().enumerate() {
        *g = 1.0 + 0.5 * i as f64;
    }
}

/// `∂L/∂y0 ++ ∂L/∂θ` of one per-path adjoint solve.
fn concat_grads(g: &AdjointGrad) -> Vec<f64> {
    let mut cat = g.dy0.clone();
    cat.extend_from_slice(&g.dtheta);
    cat
}

#[test]
fn adjoint_matches_fd_tanh_diagonal_across_dims_and_steps() {
    for &d in &[2usize, 4] {
        for &n in &[16usize, 64] {
            let sde = TanhDiagonal::new(d, 7 + d as u64);
            let theta0 = sde.params_flat();
            let y0: Vec<f64> = (0..d).map(|i| 0.1 + 0.04 * i as f64).collect();
            let noise = CounterGridNoise::new(3 * n as u64 + d as u64, d, 0.0, 1.0, n);
            let loss = |th: &[f64], y0v: &[f64]| -> f64 {
                let s =
                    TanhDiagonal::from_matrices(d, th[..d * d].to_vec(), th[d * d..].to_vec());
                let mut solver = ReversibleHeun::new(&s, 0.0, y0v);
                let mut pn = noise.path(0);
                let traj = integrate(&s, &mut solver, &mut pn, y0v, 0.0, 1.0, n);
                traj[traj.len() - d..].iter().sum()
            };
            let mut pn = noise.path(0);
            let adj = adjoint_solve(
                &sde,
                &y0,
                0.0,
                1.0,
                n,
                &mut pn,
                BackwardMode::Reconstruct,
                |_z, gz| gz.fill(1.0),
            )
            .expect("fault-free by construction"); // test-only unwrap: no injection here
            let mut fd = central_gradient(|yy| loss(&theta0, yy), &y0, 1e-5);
            fd.extend(central_gradient(|th| loss(th, &y0), &theta0, 1e-5));
            let rel = relative_l1(&concat_grads(&adj), &fd);
            assert!(rel <= 1e-6, "d={d} n={n}: adjoint-vs-FD rel L1 {rel:e}");
        }
    }
}

#[test]
fn adjoint_matches_fd_on_ou_to_1e6() {
    // The acceptance-criterion bound: ≤1e-6 relative L1 on the OU problem.
    let sde = TimeDependentOu::default();
    let theta0 = [sde.rho, sde.kappa, sde.chi];
    let n = 64usize;
    let noise = CounterGridNoise::new(41, 1, 0.0, 1.0, n);
    let loss = |th: &[f64], y0v: &[f64]| -> f64 {
        let s = TimeDependentOu { rho: th[0], kappa: th[1], chi: th[2] };
        let mut solver = ReversibleHeun::new(&s, 0.0, y0v);
        let mut pn = noise.path(0);
        let traj = integrate(&s, &mut solver, &mut pn, y0v, 0.0, 1.0, n);
        traj[traj.len() - 1]
    };
    let mut pn = noise.path(0);
    let adj = adjoint_solve(
        &sde,
        &[1.0],
        0.0,
        1.0,
        n,
        &mut pn,
        BackwardMode::Reconstruct,
        |_z, gz| gz[0] = 1.0,
    )
    .expect("fault-free by construction"); // test-only unwrap: no injection here
    let mut fd = central_gradient(|yy| loss(&theta0, yy), &[1.0], 1e-4);
    fd.extend(central_gradient(|th| loss(th, &[1.0]), &theta0, 1e-4));
    let rel = relative_l1(&concat_grads(&adj), &fd);
    assert!(rel <= 1e-6, "OU adjoint-vs-FD rel L1 {rel:e}");
}

#[test]
fn adjoint_matches_fd_dense_coupled_state_gradient() {
    let n = 24usize;
    let noise = CounterGridNoise::new(9, 3, 0.0, 1.0, n);
    let y0 = [0.3f64, -0.2];
    let loss = |y0v: &[f64]| -> f64 {
        let mut solver = ReversibleHeun::new(&DenseCoupled, 0.0, y0v);
        let mut pn = noise.path(0);
        let traj = integrate(&DenseCoupled, &mut solver, &mut pn, y0v, 0.0, 1.0, n);
        traj[traj.len() - 2..].iter().sum()
    };
    let mut pn = noise.path(0);
    let adj = adjoint_solve(
        &DenseCoupled,
        &y0,
        0.0,
        1.0,
        n,
        &mut pn,
        BackwardMode::Reconstruct,
        |_z, gz| gz.fill(1.0),
    )
    .expect("fault-free by construction"); // test-only unwrap: no injection here
    assert!(adj.dtheta.is_empty());
    let fd = central_gradient(loss, &y0, 1e-5);
    let rel = relative_l1(&adj.dy0, &fd);
    assert!(rel <= 1e-7, "DenseCoupled adjoint-vs-FD rel L1 {rel:e}");
}

/// Batch sizes around the 4-wide SIMD unroll, as pinned by the forward
/// engine's remainder-lane tests.
const REMAINDER_BATCHES: [usize; 6] = [1, 3, 4, 7, 8, 33];

/// Per-path reference: `batch` separate `adjoint_solve` runs; `dy0` lanes
/// gathered SoA, `dtheta` summed in ascending path order.
fn per_path_reference(
    sde: &TanhDiagonal,
    aos: &[f64],
    batch: usize,
    n: usize,
    noise: &CounterGridNoise,
    mode: BackwardMode,
) -> AdjointGrad {
    let dim = Sde::dim(sde);
    let pl = 2 * dim * dim;
    let mut terminal = vec![0.0; dim * batch];
    let mut dy0 = vec![0.0; dim * batch];
    let mut dtheta = vec![0.0; pl];
    for p in 0..batch {
        let y0p = &aos[p * dim..(p + 1) * dim];
        let mut pn = noise.path(p);
        let g = adjoint_solve(sde, y0p, 0.0, 1.0, n, &mut pn, mode, |_z, gz| {
            seed_per_path(gz)
        })
        .expect("fault-free by construction"); // test-only unwrap: no injection here
        for i in 0..dim {
            terminal[i * batch + p] = g.terminal[i];
            dy0[i * batch + p] = g.dy0[i];
        }
        for m in 0..pl {
            dtheta[m] += g.dtheta[m];
        }
    }
    AdjointGrad { terminal, dy0, dtheta, ddw: Vec::new(), fallbacks: 0 }
}

#[test]
fn batched_adjoint_bit_identical_to_per_path() {
    let dim = 5usize;
    let n = 12usize;
    let sde = TanhDiagonal::new(dim, 17);
    let native = TanhDiagonalBatch::from_system(TanhDiagonal::new(dim, 17));
    let seed = |_p0: usize, cl: usize, _z: &[f64], g: &mut [f64]| {
        for i in 0..5 {
            for q in 0..cl {
                g[i * cl + q] = 1.0 + 0.5 * i as f64;
            }
        }
    };
    for &batch in &REMAINDER_BATCHES {
        let aos = aos_start(dim, batch);
        let y0 = aos_to_soa(&aos, dim, batch);
        let noise = CounterGridNoise::new(77, dim, 0.0, 1.0, n);
        for mode in [BackwardMode::Reconstruct, BackwardMode::Tape] {
            let reference = per_path_reference(&sde, &aos, batch, n, &noise, mode);
            // The chunk fan-out now runs on the same work-stealing deque
            // pool as the forward engine (`map_chunks`); results stay keyed
            // by chunk index, so every schedule must produce the same bits.
            for (threads, chunk) in [(1usize, batch), (1, 2), (3, 2), (2, 4), (4, 1), (8, 3)] {
                let opts = BatchOptions { threads, chunk, ..Default::default() };
                let got = adjoint_solve_batched(
                    &native, &noise, &y0, batch, 0.0, 1.0, n, mode, &opts, &seed,
                )
                .expect("fault-free by construction"); // test-only unwrap: no injection here
                assert_eq!(
                    got.terminal, reference.terminal,
                    "terminal diverged: batch={batch} mode={mode:?} t={threads} c={chunk}"
                );
                assert_eq!(
                    got.dy0, reference.dy0,
                    "dy0 diverged: batch={batch} mode={mode:?} t={threads} c={chunk}"
                );
                assert_eq!(
                    got.dtheta, reference.dtheta,
                    "dtheta diverged: batch={batch} mode={mode:?} t={threads} c={chunk}"
                );
            }
        }
    }
}

#[test]
fn native_batch_vjps_match_blanket_adapter_bitwise() {
    let dim = 6usize;
    let n = 10usize;
    let adapter = TanhDiagonal::new(dim, 21);
    let native = TanhDiagonalBatch::new(dim, 21);
    let seed = |_p0: usize, cl: usize, _z: &[f64], g: &mut [f64]| {
        for i in 0..6 {
            for q in 0..cl {
                g[i * cl + q] = 1.0 - 0.25 * i as f64;
            }
        }
    };
    for &batch in &[1usize, 5, 33] {
        let y0 = aos_to_soa(&aos_start(dim, batch), dim, batch);
        let noise = CounterGridNoise::new(3, dim, 0.0, 1.0, n);
        let opts = BatchOptions { threads: 1, chunk: 16, ..Default::default() };
        let a = adjoint_solve_batched(
            &adapter,
            &noise,
            &y0,
            batch,
            0.0,
            1.0,
            n,
            BackwardMode::Reconstruct,
            &opts,
            &seed,
        )
        .expect("fault-free by construction"); // test-only unwrap: no injection here
        let b = adjoint_solve_batched(
            &native,
            &noise,
            &y0,
            batch,
            0.0,
            1.0,
            n,
            BackwardMode::Reconstruct,
            &opts,
            &seed,
        )
        .expect("fault-free by construction"); // test-only unwrap: no injection here
        assert_eq!(a.terminal, b.terminal, "terminal diverged at batch {batch}");
        assert_eq!(a.dy0, b.dy0, "dy0 diverged at batch {batch}");
        assert_eq!(a.dtheta, b.dtheta, "dtheta diverged at batch {batch}");
    }
}

#[test]
fn dense_coupled_batched_adjoint_matches_per_path() {
    // Dense-noise path (e=2, d=3) through the native SoA VJPs.
    let (dim, n) = (2usize, 14usize);
    let seed = |_p0: usize, cl: usize, _z: &[f64], g: &mut [f64]| {
        for i in 0..2 {
            for q in 0..cl {
                g[i * cl + q] = 1.0 + i as f64;
            }
        }
    };
    for &batch in &[1usize, 7, 33] {
        let aos = aos_start(dim, batch);
        let y0 = aos_to_soa(&aos, dim, batch);
        let noise = CounterGridNoise::new(11, 3, 0.0, 1.0, n);
        let opts = BatchOptions { threads: 1, chunk: 8, ..Default::default() };
        let got = adjoint_solve_batched(
            &DenseCoupledBatch,
            &noise,
            &y0,
            batch,
            0.0,
            1.0,
            n,
            BackwardMode::Reconstruct,
            &opts,
            &seed,
        )
        .expect("fault-free by construction"); // test-only unwrap: no injection here
        for p in 0..batch {
            let y0p = &aos[p * dim..(p + 1) * dim];
            let mut pn = noise.path(p);
            let g = adjoint_solve(
                &DenseCoupled,
                y0p,
                0.0,
                1.0,
                n,
                &mut pn,
                BackwardMode::Reconstruct,
                |_z, gz| {
                    gz[0] = 1.0;
                    gz[1] = 2.0;
                },
            )
            .expect("fault-free by construction"); // test-only unwrap: no injection here
            for i in 0..dim {
                assert_eq!(got.dy0[i * batch + p], g.dy0[i], "path {p} component {i}");
            }
        }
    }
}

#[test]
fn ou_machine_precision_gradient_roundtrip() {
    // Closed-form OU: additive noise and linear drift make the per-step
    // Jacobian the *constant* 2×2 matrix
    //   [ 1 − κh      ½κ²h²  ]
    //   [ 2          −1 − κh ],
    // so ∂z_N/∂y0 = [1, 0]·M^N·[1; 1] exactly. The O(1)-memory
    // reconstruction adjoint must reproduce it — and the stored-tape
    // gradient — to <1e-10 at every step count: zero truncation error.
    let sde = TimeDependentOu::default();
    let kappa = sde.kappa;
    for &n in &[16usize, 64, 256] {
        let noise = CounterGridNoise::new(n as u64 + 5, 1, 0.0, 1.0, n);
        let run = |mode| {
            let mut pn = noise.path(0);
            adjoint_solve(&sde, &[1.0], 0.0, 1.0, n, &mut pn, mode, |_z, gz| gz[0] = 1.0)
                .expect("fault-free by construction") // test-only unwrap: no injection here
        };
        let rec = run(BackwardMode::Reconstruct);
        let tape = run(BackwardMode::Tape);
        let h = 1.0 / n as f64;
        let (mut rz, mut rzh) = (1.0f64, 0.0f64);
        for _ in 0..n {
            let nz = rz * (1.0 - kappa * h) + rzh * 2.0;
            let nzh = rz * (0.5 * kappa * kappa * h * h) + rzh * (-1.0 - kappa * h);
            rz = nz;
            rzh = nzh;
        }
        let exact = rz + rzh;
        let rel_exact = (rec.dy0[0] - exact).abs() / exact.abs().max(1e-300);
        assert!(
            rel_exact < 1e-10,
            "n={n}: adjoint dy0 {} vs closed form {} (rel {rel_exact:e})",
            rec.dy0[0],
            exact
        );
        let roundtrip = relative_l1(&concat_grads(&rec), &concat_grads(&tape));
        assert!(roundtrip < 1e-10, "n={n}: rec-vs-tape rel L1 {roundtrip:e}");

        // z_N is affine in (ρ, χ): central differences are exact at ANY
        // step, so even a huge h pins the adjoint θ-gradient to roundoff.
        let loss = |th: &[f64]| -> f64 {
            let s = TimeDependentOu { rho: th[0], kappa, chi: th[1] };
            let mut solver = ReversibleHeun::new(&s, 0.0, &[1.0]);
            let mut pn = noise.path(0);
            let traj = integrate(&s, &mut solver, &mut pn, &[1.0], 0.0, 1.0, n);
            traj[traj.len() - 1]
        };
        let fd = central_gradient(loss, &[sde.rho, sde.chi], 0.25);
        for (got, want) in [(rec.dtheta[0], fd[0]), (rec.dtheta[2], fd[1])] {
            let rel = (got - want).abs() / want.abs().max(1e-300);
            assert!(rel < 1e-10, "n={n}: affine θ-gradient {got} vs FD {want}");
        }
    }
}

#[test]
fn vjp_harness_validates_every_impl_at_several_tolerances() {
    // (h, tol): truncation-dominated at coarse h, then roundoff-floor.
    let probes = [(1e-3, 1e-4), (1e-4, 1e-6), (1e-5, 1e-8)];
    let run = |name: &str, err_at: &dyn Fn(f64) -> f64| {
        for &(h, tol) in &probes {
            let err = err_at(h);
            assert!(err < tol, "{name}: VJP-vs-FD error {err:e} at h={h:e}");
        }
    };
    run("scalar_linear", &|h| {
        max_vjp_fd_error(
            |p: &[f64]| ScalarLinear { a: p[0], b: p[1] },
            &[0.3, 0.5],
            0.0,
            &[1.2],
            &[0.7],
            &[-0.4],
            &[0.9],
            h,
        )
    });
    run("anharmonic", &|h| {
        max_vjp_fd_error(
            |p: &[f64]| Anharmonic { sigma: p[0] },
            &[0.8],
            0.0,
            &[0.6],
            &[1.1],
            &[0.5],
            &[0.3],
            h,
        )
    });
    run("time_dependent_ou", &|h| {
        max_vjp_fd_error(
            |p: &[f64]| TimeDependentOu { rho: p[0], kappa: p[1], chi: p[2] },
            &[0.02, 0.1, 0.4],
            0.7,
            &[0.9],
            &[1.3],
            &[-0.8],
            &[0.2],
            h,
        )
    });
    run("tanh_diagonal", &|h| {
        let d = 3usize;
        let base = TanhDiagonal::new(d, 13);
        let theta = base.params_flat();
        max_vjp_fd_error(
            |p: &[f64]| TanhDiagonal::from_matrices(3, p[..9].to_vec(), p[9..].to_vec()),
            &theta,
            0.0,
            &[0.2, -0.1, 0.3],
            &[0.5, 0.6, 0.7],
            &[-0.3, 0.1, 0.2],
            &[0.07, 0.14, 0.21],
            h,
        )
    });
    run("dense_coupled", &|h| {
        max_vjp_fd_error(
            |_: &[f64]| DenseCoupled,
            &[],
            0.3,
            &[0.4, -0.2],
            &[0.8, -0.6],
            &[0.5, 0.9],
            &[0.11, -0.07, 0.05],
            h,
        )
    });
}

#[test]
fn brownian_interval_backward_replay_is_bit_identical() {
    // The Brownian Interval's raison d'être: the backward pass replays the
    // exact forward increments. One fill_grid descent (GridReplayNoise)
    // must produce bit-identical gradients to per-step interval queries.
    let d = 2usize;
    let n = 20usize;
    let sde = TanhDiagonal::new(d, 31);
    let y0 = [0.15f64, -0.05];
    let via_queries = {
        let mut bi = BrownianInterval::new(0.0, 1.0, d, 99);
        let mut noise = NoiseFromSource::new(&mut bi);
        adjoint_solve(
            &sde,
            &y0,
            0.0,
            1.0,
            n,
            &mut noise,
            BackwardMode::Reconstruct,
            |_z, gz| gz.fill(1.0),
        )
        .expect("fault-free by construction") // test-only unwrap: no injection here
    };
    let via_replay = {
        let mut bi = BrownianInterval::new(0.0, 1.0, d, 99);
        let mut noise = GridReplayNoise::from_source(&mut bi, 0.0, 1.0, n);
        adjoint_solve(
            &sde,
            &y0,
            0.0,
            1.0,
            n,
            &mut noise,
            BackwardMode::Reconstruct,
            |_z, gz| gz.fill(1.0),
        )
        .expect("fault-free by construction") // test-only unwrap: no injection here
    };
    assert_eq!(via_queries.terminal, via_replay.terminal);
    assert_eq!(via_queries.dy0, via_replay.dy0);
    assert_eq!(via_queries.dtheta, via_replay.dtheta);
}

#[test]
fn native_gradient_drives_optimizer_end_to_end() {
    // Fit ScalarLinear's (a, b) so the terminal value on a fixed noise
    // realisation hits a target: adjoint gradient → nn::optim::step_f64.
    let n = 32usize;
    let noise = CounterGridNoise::new(55, 1, 0.0, 1.0, n);
    let target = 2.0f64;
    let loss_of = |params: &[f32]| -> (f64, Vec<f64>) {
        let sde = ScalarLinear { a: params[0] as f64, b: params[1] as f64 };
        let mut pn = noise.path(0);
        let g = adjoint_solve(
            &sde,
            &[1.0],
            0.0,
            1.0,
            n,
            &mut pn,
            BackwardMode::Reconstruct,
            |z, gz| gz[0] = 2.0 * (z[0] - target),
        )
        .expect("fault-free by construction"); // test-only unwrap: no injection here
        let resid = g.terminal[0] - target;
        (resid * resid, g.dtheta)
    };
    let mut params = [0.1f32, 0.3];
    let (initial, _) = loss_of(&params);
    let mut opt = Adam::new(0.05, 2);
    for _ in 0..60 {
        let (_, grad) = loss_of(&params);
        step_f64(&mut opt, &mut params, &grad);
    }
    let (fin, _) = loss_of(&params);
    assert!(
        fin < 0.25 * initial,
        "adjoint-driven training failed to descend: {initial} -> {fin}"
    );
}
