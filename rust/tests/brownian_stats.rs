//! Distributional tests over the Brownian sources (paper Section 4):
//! increment mean/variance via chi-squared bounds, cross-interval
//! independence, and `fill_grid`/per-step agreement through `reseed()` —
//! plus robustness pins: LRU eviction under adversarial out-of-order
//! access and mid-trajectory `reseed` must both be bit-exact.
//!
//! Each source simulates `size` independent scalar Brownian motions, so one
//! wide instance gives thousands of iid samples of any increment. With the
//! seeds fixed the statistics are deterministic; the bounds are set at six
//! standard deviations of the relevant sampling distribution — loose enough
//! never to flake on a correct generator, tight enough to catch a wrong
//! variance scale, a mean offset, or correlated bridge noise.

use neuralsde::brownian::{BrownianInterval, BrownianSource, IntervalOptions, VirtualBrownianTree};

const N: usize = 16_384;

/// Mean of the samples.
fn mean(w: &[f32]) -> f64 {
    w.iter().map(|&x| x as f64).sum::<f64>() / w.len() as f64
}

/// `Σ w_i² / var` — chi-squared distributed with `w.len()` degrees of
/// freedom when `w_i ~ N(0, var)` iid.
fn chi_sq(w: &[f32], var: f64) -> f64 {
    w.iter().map(|&x| (x as f64) * (x as f64) / var).sum::<f64>()
}

/// Pearson correlation across channels.
fn corr(a: &[f32], b: &[f32]) -> f64 {
    let (ma, mb) = (mean(a), mean(b));
    let mut num = 0.0;
    let mut va = 0.0;
    let mut vb = 0.0;
    for i in 0..a.len() {
        let (x, y) = (a[i] as f64 - ma, b[i] as f64 - mb);
        num += x * y;
        va += x * x;
        vb += y * y;
    }
    num / (va.sqrt() * vb.sqrt())
}

/// |X̄| ≤ 6 sd/√n and |χ²/n − 1| ≤ 6 √(2/n), the 6σ bounds used throughout.
fn assert_moments(w: &[f32], var: f64, label: &str) {
    let n = w.len() as f64;
    let m = mean(w);
    let mean_bound = 6.0 * (var / n).sqrt();
    assert!(m.abs() < mean_bound, "{label}: mean {m} exceeds {mean_bound}");
    let s = chi_sq(w, var) / n;
    let chi_bound = 6.0 * (2.0 / n).sqrt();
    assert!(
        (s - 1.0).abs() < chi_bound,
        "{label}: chi-squared/n = {s}, expected within {chi_bound} of 1"
    );
}

#[test]
fn brownian_interval_increment_moments_chi_squared() {
    let mut bi = BrownianInterval::new(0.0, 1.0, N, 424_242);
    // Whole-span increment, then conditioned sub-increments: all must carry
    // N(0, t - s) marginals.
    for (s, t) in [(0.0, 1.0), (0.2, 0.7), (0.7, 0.95), (0.0, 0.2)] {
        let w = bi.increment_vec(s, t);
        assert_moments(&w, t - s, &format!("BI [{s},{t}]"));
    }
}

#[test]
fn virtual_tree_increment_moments_chi_squared() {
    let mut vbt = VirtualBrownianTree::new(0.0, 1.0, N, 3_131, 1e-5);
    for (s, t) in [(0.0, 1.0), (0.25, 0.5), (0.5, 0.9)] {
        let w = vbt.increment_vec(s, t);
        assert_moments(&w, t - s, &format!("VBT [{s},{t}]"));
    }
}

#[test]
fn brownian_interval_disjoint_increments_independent() {
    let mut bi = BrownianInterval::new(0.0, 1.0, N, 99);
    let w1 = bi.increment_vec(0.1, 0.4);
    let w2 = bi.increment_vec(0.4, 0.9); // adjacent
    let w3 = bi.increment_vec(0.93, 0.99); // separated
    let bound = 6.0 / (N as f64).sqrt();
    for (a, b, label) in
        [(&w1, &w2, "adjacent"), (&w1, &w3, "separated"), (&w2, &w3, "disjoint")]
    {
        let r = corr(a, b);
        assert!(r.abs() < bound, "{label}: correlation {r} exceeds {bound}");
    }
}

#[test]
fn virtual_tree_disjoint_increments_independent() {
    let mut vbt = VirtualBrownianTree::new(0.0, 1.0, N, 17, 1e-5);
    let w1 = vbt.increment_vec(0.05, 0.35);
    let w2 = vbt.increment_vec(0.35, 0.8);
    let bound = 6.0 / (N as f64).sqrt();
    let r = corr(&w1, &w2);
    assert!(r.abs() < bound, "correlation {r} exceeds {bound}");
}

#[test]
fn brownian_interval_grid_steps_pooled_chi_squared() {
    // Every step of a training grid at once: 32 steps × N channels pooled
    // into one chi-squared statistic (each step has variance h).
    let steps = 32usize;
    let size = 2_048usize;
    let h = 1.0 / steps as f64;
    let ts: Vec<f64> = (0..=steps).map(|k| k as f64 * h).collect();
    let mut bi = BrownianInterval::new(0.0, 1.0, size, 7_777);
    let mut out = vec![0.0f32; steps * size];
    bi.fill_grid(&ts, &mut out);
    assert_moments(&out, h, "BI pooled grid steps");
}

#[test]
fn brownian_interval_fill_grid_matches_steps_after_reseed() {
    let steps = 24usize;
    let size = 16usize;
    let ts: Vec<f64> = (0..=steps).map(|k| k as f64 / steps as f64).collect();
    let mut bulk = BrownianInterval::new(0.0, 1.0, size, 1);
    let mut steppy = BrownianInterval::new(0.0, 1.0, size, 1);
    let mut out = vec![0.0f32; steps * size];
    bulk.fill_grid(&ts, &mut out); // build both tree shapes
    for k in 0..steps {
        let _ = steppy.increment_vec(ts[k], ts[k + 1]);
    }
    for seed in [2u64, 3, 4] {
        bulk.reseed(seed);
        steppy.reseed(seed);
        bulk.fill_grid(&ts, &mut out);
        for k in 0..steps {
            assert_eq!(
                &out[k * size..(k + 1) * size],
                steppy.increment_vec(ts[k], ts[k + 1]).as_slice(),
                "seed {seed} step {k}"
            );
        }
    }
}

#[test]
fn lru_eviction_under_adversarial_out_of_order_access_is_bit_exact() {
    // A capacity-2 cache evicts on almost every query, so each value below
    // is recomputed through an ancestor walk; the 4096-entry twin serves
    // the same sequence mostly from cache. The increments must not depend
    // on which of the two happened.
    let steps = 64usize;
    let size = 8usize;
    let small = IntervalOptions { cache_capacity: 2, preseed_depth: 0 };
    let big = IntervalOptions { cache_capacity: 4096, preseed_depth: 0 };
    let mut a = BrownianInterval::with_options(0.0, 1.0, size, 31, small);
    let mut b = BrownianInterval::with_options(0.0, 1.0, size, 31, big);
    // Out-of-order step permutation (37 is coprime with 64), interleaved
    // with coarse multi-scale spans that keep churning the tiny cache.
    let query = |k: usize| -> (usize, f64, f64) {
        let j = (k * 37 + 11) % steps;
        (j, j as f64 / steps as f64, (j + 1) as f64 / steps as f64)
    };
    let mut firsts = vec![Vec::new(); steps];
    for k in 0..steps {
        let (j, s, t) = query(k);
        let wa = a.increment_vec(s, t);
        assert_eq!(wa, b.increment_vec(s, t), "query {k}: tiny cache diverged");
        firsts[j] = wa;
        let coarse = (k % 4) as f64 * 0.25;
        assert_eq!(
            a.increment_vec(coarse, coarse + 0.25),
            b.increment_vec(coarse, coarse + 0.25),
            "coarse query {k}: tiny cache diverged"
        );
    }
    // Second pass in natural order: everything has long been evicted from
    // the capacity-2 cache, so every value is recomputed — the bits must
    // reproduce the first pass exactly.
    for j in 0..steps {
        let (s, t) = (j as f64 / steps as f64, (j + 1) as f64 / steps as f64);
        assert_eq!(a.increment_vec(s, t), firsts[j], "step {j}: eviction changed the bits");
    }
}

#[test]
fn reseed_mid_trajectory_matches_cold_interval_bitwise() {
    let steps = 48usize;
    let size = 8usize;
    let ts: Vec<f64> = (0..=steps).map(|k| k as f64 / steps as f64).collect();
    let mut warm = BrownianInterval::new(0.0, 1.0, size, 77);
    // Walk half the trajectory under the old seed (builds the tree shape
    // and fills the cache with old-stream values)...
    for k in 0..steps / 2 {
        let _ = warm.increment_vec(ts[k], ts[k + 1]);
    }
    // ...then redraw the path mid-trajectory: every stale cached value must
    // be invalidated, and the redrawn path must be the cold-start path.
    warm.reseed(1234);
    let mut cold = BrownianInterval::new(0.0, 1.0, size, 1234);
    for k in 0..steps {
        assert_eq!(
            warm.increment_vec(ts[k], ts[k + 1]),
            cold.increment_vec(ts[k], ts[k + 1]),
            "step {k}: reseeded interval diverged from a cold one"
        );
    }
    // The backward re-query (the adjoint's access pattern) must agree too.
    for k in (0..steps).rev() {
        assert_eq!(
            warm.increment_vec(ts[k], ts[k + 1]),
            cold.increment_vec(ts[k], ts[k + 1]),
            "backward step {k}: reseeded interval diverged from a cold one"
        );
    }
}

#[test]
fn virtual_tree_fill_grid_matches_steps_after_reseed() {
    let steps = 12usize;
    let size = 8usize;
    let ts: Vec<f64> = (0..=steps).map(|k| k as f64 / steps as f64).collect();
    let mut bulk = VirtualBrownianTree::new(0.0, 1.0, size, 5, 1e-5);
    let mut steppy = VirtualBrownianTree::new(0.0, 1.0, size, 5, 1e-5);
    let mut out = vec![0.0f32; steps * size];
    for seed in [6u64, 7] {
        bulk.reseed(seed);
        steppy.reseed(seed);
        bulk.fill_grid(&ts, &mut out);
        for k in 0..steps {
            assert_eq!(
                &out[k * size..(k + 1) * size],
                steppy.increment_vec(ts[k], ts[k + 1]).as_slice(),
                "seed {seed} step {k}"
            );
        }
    }
}
