//! Property-based tests over the Layer-3 invariants (hand-rolled
//! generative testing — seeded random cases with shrink-free assertion
//! messages; the offline build has no proptest crate).

use neuralsde::brownian::{
    splitmix64, BrownianInterval, BrownianSource, IntervalOptions, LruCache, SplitPrng,
    StoredPath, VirtualBrownianTree,
};
use neuralsde::metrics::{sig_dim, signature};
use neuralsde::solvers::systems::{Anharmonic, ScalarLinear, TanhDiagonal};
use neuralsde::solvers::{ReversibleHeun, Sde};

fn cases(seed: u64, n: usize) -> impl Iterator<Item = u64> {
    (0..n as u64).map(move |i| splitmix64(seed.wrapping_add(i)))
}

/// Random query sequences never violate chain additivity within fp error.
#[test]
fn prop_brownian_interval_chain_additivity() {
    for case in cases(1, 30) {
        let mut rng = SplitPrng::new(case);
        let mut bi = BrownianInterval::new(0.0, 1.0, 3, case);
        for _ in 0..20 {
            let a = rng.next_uniform();
            let b = rng.next_uniform();
            let (s, t) = if a < b { (a, b) } else { (b, a) };
            if t - s < 1e-6 {
                continue;
            }
            let m = 0.5 * (s + t);
            let whole = bi.increment_vec(s, t);
            let l = bi.increment_vec(s, m);
            let r = bi.increment_vec(m, t);
            for c in 0..3 {
                assert!(
                    (whole[c] - (l[c] + r[c])).abs() < 1e-4,
                    "case {case}: [{s},{t}] channel {c}: {} vs {}",
                    whole[c],
                    l[c] + r[c]
                );
            }
        }
    }
}

/// The LRU capacity must never change query *values*, only speed.
#[test]
fn prop_cache_capacity_invariance_random_queries() {
    for case in cases(2, 15) {
        let small = IntervalOptions { cache_capacity: 2, preseed_depth: 0 };
        let big = IntervalOptions { cache_capacity: 1 << 14, preseed_depth: 0 };
        let mut a = BrownianInterval::with_options(0.0, 1.0, 2, case, small);
        let mut b = BrownianInterval::with_options(0.0, 1.0, 2, case, big);
        let mut rng = SplitPrng::new(case ^ 0xC0);
        for _ in 0..40 {
            let s = rng.next_uniform() * 0.98;
            let t = s + 0.005 + rng.next_uniform() * (0.99 - s);
            assert_eq!(a.increment_vec(s, t), b.increment_vec(s, t), "case {case}");
        }
    }
}

/// Querying the same (seeded) source twice is idempotent for every backend.
#[test]
fn prop_all_sources_deterministic() {
    for case in cases(3, 10) {
        let queries: Vec<(f64, f64)> = {
            let mut rng = SplitPrng::new(case);
            (0..10)
                .map(|_| {
                    let s = rng.next_uniform() * 0.9;
                    (s, s + 0.01 + rng.next_uniform() * (0.99 - s) * 0.5)
                })
                .collect()
        };
        let run = |src: &mut dyn BrownianSource| -> Vec<Vec<f32>> {
            queries.iter().map(|&(s, t)| src.increment_vec(s, t)).collect()
        };
        let mut bi1 = BrownianInterval::new(0.0, 1.0, 2, case);
        let mut bi2 = BrownianInterval::new(0.0, 1.0, 2, case);
        assert_eq!(run(&mut bi1), run(&mut bi2));
        let mut vt1 = VirtualBrownianTree::new(0.0, 1.0, 2, case, 1e-5);
        let mut vt2 = VirtualBrownianTree::new(0.0, 1.0, 2, case, 1e-5);
        assert_eq!(run(&mut vt1), run(&mut vt2));
        let mut sp1 = StoredPath::new(0.0, 1.0, 2, case, 128);
        let mut sp2 = StoredPath::new(0.0, 1.0, 2, case, 128);
        assert_eq!(run(&mut sp1), run(&mut sp2));
    }
}

/// Reversible Heun: forward∘reverse == identity across random SDEs, step
/// counts and dimensions.
#[test]
fn prop_revheun_roundtrip_random_systems() {
    for case in cases(4, 12) {
        let dim = 1 + (case % 7) as usize;
        let n = 16 + (case % 64) as usize;
        let sde = TanhDiagonal::new(dim, case);
        let y0: Vec<f64> = (0..dim).map(|i| 0.1 * i as f64 - 0.2).collect();
        let mut solver = ReversibleHeun::new(&sde, 0.0, &y0);
        let init = solver.state().clone();
        let mut rng = SplitPrng::new(case ^ 0xABC);
        let dt = 1.0 / n as f64;
        let sd = dt.sqrt();
        let dws: Vec<Vec<f64>> = (0..n)
            .map(|_| (0..dim).map(|_| rng.next_normal_pair().0 * sd).collect())
            .collect();
        for (k, dw) in dws.iter().enumerate() {
            solver.forward_step(&sde, k as f64 * dt, dt, dw);
        }
        for (k, dw) in dws.iter().enumerate().rev() {
            solver.reverse_step(&sde, (k + 1) as f64 * dt, dt, dw);
        }
        let err = solver.state().max_abs_diff(&init);
        assert!(err < 1e-8, "case {case} (dim {dim}, n {n}): round-trip {err}");
    }
}

/// Linear-SDE strong error vs the exact solution decreases with step count.
#[test]
fn prop_revheun_converges_to_exact_solution() {
    let sde = ScalarLinear { a: 0.4, b: 0.3 };
    let mut errs = Vec::new();
    for n in [16usize, 64, 256] {
        let mut total = 0.0;
        for case in cases(5, 40) {
            let mut rng = SplitPrng::new(case);
            let dt = 1.0 / n as f64;
            let mut solver = ReversibleHeun::new(&sde, 0.0, &[1.0]);
            let mut w = 0.0;
            let mut y = [1.0f64];
            for k in 0..n {
                let dw = rng.next_normal_pair().0 * dt.sqrt();
                w += dw;
                neuralsde::solvers::FixedStepSolver::step(
                    &mut solver, &sde, k as f64 * dt, dt, &[dw], &mut y,
                );
            }
            let exact = (sde.a * 1.0 + sde.b * w).exp();
            total += (y[0] - exact).abs();
        }
        errs.push(total / 40.0);
    }
    assert!(errs[2] < errs[0], "no convergence: {errs:?}");
}

/// Signature shuffle identity at depth 2: S⁽ⁱ⁾S⁽ʲ⁾ = S⁽ⁱʲ⁾ + S⁽ʲⁱ⁾.
#[test]
fn prop_signature_shuffle_identity() {
    for case in cases(6, 20) {
        let mut rng = SplitPrng::new(case);
        let c = 2 + (case % 2) as usize;
        let len = 4 + (case % 8) as usize;
        let path: Vec<f64> = (0..len * c).map(|_| rng.next_normal_pair().0).collect();
        let sig = signature(&path, len, c, 2);
        for i in 0..c {
            for j in 0..c {
                let lhs = sig[i] * sig[j];
                let rhs = sig[c + i * c + j] + sig[c + j * c + i];
                assert!(
                    (lhs - rhs).abs() < 1e-9,
                    "case {case}: shuffle identity failed at ({i},{j}): {lhs} vs {rhs}"
                );
            }
        }
    }
}

/// sig_dim matches the produced feature length for random (c, depth).
#[test]
fn prop_sig_dim_consistent() {
    for case in cases(7, 12) {
        let c = 1 + (case % 4) as usize;
        let depth = 1 + (case % 4) as usize;
        let path = vec![0.5; 6 * c];
        assert_eq!(signature(&path, 6, c, depth).len(), sig_dim(c, depth));
    }
}

/// Anharmonic drift is bounded by 1, so solutions grow at most linearly —
/// solver must not blow up over long horizons.
#[test]
fn prop_solver_stability_long_horizon() {
    let sde = Anharmonic { sigma: 0.5 };
    for case in cases(8, 6) {
        let n = 2048;
        let mut solver = ReversibleHeun::new(&sde, 0.0, &[0.0]);
        let mut rng = SplitPrng::new(case);
        let dt = 8.0 / n as f64;
        let mut y = [0.0f64];
        for k in 0..n {
            let dw = rng.next_normal_pair().0 * dt.sqrt();
            neuralsde::solvers::FixedStepSolver::step(
                &mut solver, &sde, k as f64 * dt, dt, &[dw], &mut y,
            );
        }
        assert!(y[0].abs() < 8.0 + 6.0, "case {case}: |y| = {}", y[0].abs());
    }
}

/// LRU under adversarial key reuse still honours capacity and recency.
#[test]
fn prop_lru_capacity_respected() {
    for case in cases(9, 10) {
        let cap = 1 + (case % 16) as usize;
        let mut c: LruCache<u64, u64> = LruCache::new(cap);
        let mut rng = SplitPrng::new(case);
        for _ in 0..1000 {
            let k = rng.next_u64() % 64;
            c.put(k, k * 2);
            assert!(c.len() <= cap, "case {case}: len {} > cap {cap}", c.len());
        }
    }
}
