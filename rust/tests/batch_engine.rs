//! Property tests for the batched structure-of-arrays solve engine:
//!
//! * batched `integrate_batched` matches per-path `integrate` **bit-for-bit**
//!   for every solver, on diagonal and dense-noise systems — including batch
//!   sizes that exercise the SIMD kernels' remainder lanes (1, 3, 4, 7, 8,
//!   33 around the 4-wide unroll);
//! * the native hand-batched systems (`TanhDiagonalBatch`,
//!   `DenseCoupledBatch`) agree with the blanket gather/scatter adapter
//!   bit-for-bit;
//! * the batched reversible Heun round-trips forward/reverse to <1e-10 per
//!   path (algebraic reversibility survives batching);
//! * results are identical across 1/2/4 worker threads and across chunk
//!   sizes (the work-stealing fan-out is a pure work partition);
//! * the diagonal-noise fast path agrees with the dense path.

use neuralsde::solvers::systems::{
    DenseCoupled, DenseCoupledBatch, TanhDiagonal, TanhDiagonalBatch,
};
use neuralsde::solvers::{
    aos_to_soa, integrate, integrate_batched, BatchEulerMaruyama, BatchHeun, BatchMidpoint,
    BatchNoise, BatchOptions, BatchReversibleHeun, BatchSde, BatchStepper, CounterGridNoise,
    EulerMaruyama, Heun, Lane, Midpoint, ReversibleHeun, Sde,
};

/// Forwards a diagonal system through the dense code path (suppresses the
/// `diffusion_is_diagonal` advertisement).
struct DenseWrap<'a>(&'a TanhDiagonal);

impl Sde for DenseWrap<'_> {
    fn dim(&self) -> usize {
        Sde::dim(self.0)
    }
    fn noise_dim(&self) -> usize {
        Sde::noise_dim(self.0)
    }
    fn drift(&self, t: f64, y: &[f64], out: &mut [f64]) {
        self.0.drift(t, y, out);
    }
    fn diffusion(&self, t: f64, y: &[f64], out: &mut [f64]) {
        self.0.diffusion(t, y, out);
    }
    // diffusion_is_diagonal: default false — dense path.
}

/// Per-path starting states, slightly different per path so lane mixups
/// would be caught.
fn aos_start(dim: usize, batch: usize) -> Vec<f64> {
    (0..batch * dim).map(|x| 0.02 * (x % 17) as f64 - 0.1).collect()
}

/// Assert SoA trajectory equals the per-path trajectory of path `p` exactly.
fn assert_path_matches(traj: &[f64], per_path: &[f64], dim: usize, batch: usize, p: usize) {
    let n_points = per_path.len() / dim;
    assert_eq!(traj.len(), n_points * dim * batch);
    for k in 0..n_points {
        for i in 0..dim {
            let a = traj[k * dim * batch + i * batch + p];
            let b = per_path[k * dim + i];
            assert!(
                a == b,
                "path {p} step {k} component {i}: batched {a:e} vs per-path {b:e}"
            );
        }
    }
}

#[test]
fn batched_matches_per_path_bitwise_diagonal_system() {
    let sde = TanhDiagonal::new(8, 7);
    let (dim, batch, n) = (8usize, 13usize, 25usize);
    let aos = aos_start(dim, batch);
    let y0 = aos_to_soa(&aos, dim, batch);
    let noise = CounterGridNoise::new(42, dim, 0.0, 1.0, n);
    // uneven tail chunk
    let opts = BatchOptions { threads: 1, chunk: 4, ..Default::default() };
    let run = |which: &str| -> Vec<f64> {
        match which {
            "euler" => integrate_batched::<BatchEulerMaruyama, _, _>(
                &sde, &noise, &y0, batch, 0.0, 1.0, n, &opts,
            ),
            "midpoint" => integrate_batched::<BatchMidpoint, _, _>(
                &sde, &noise, &y0, batch, 0.0, 1.0, n, &opts,
            ),
            "heun" => integrate_batched::<BatchHeun, _, _>(
                &sde, &noise, &y0, batch, 0.0, 1.0, n, &opts,
            ),
            _ => integrate_batched::<BatchReversibleHeun, _, _>(
                &sde, &noise, &y0, batch, 0.0, 1.0, n, &opts,
            ),
        }
        .expect("fault-free by construction") // test-only unwrap: no injection here
    };
    for which in ["euler", "midpoint", "heun", "revheun"] {
        let traj = run(which);
        for p in 0..batch {
            let y0p = &aos[p * dim..(p + 1) * dim];
            let mut pn = noise.path(p);
            let per_path = match which {
                "euler" => {
                    let mut s = EulerMaruyama::new(dim, dim);
                    integrate(&sde, &mut s, &mut pn, y0p, 0.0, 1.0, n)
                }
                "midpoint" => {
                    let mut s = Midpoint::new(dim, dim);
                    integrate(&sde, &mut s, &mut pn, y0p, 0.0, 1.0, n)
                }
                "heun" => {
                    let mut s = Heun::new(dim, dim);
                    integrate(&sde, &mut s, &mut pn, y0p, 0.0, 1.0, n)
                }
                _ => {
                    let mut s = ReversibleHeun::new(&sde, 0.0, y0p);
                    integrate(&sde, &mut s, &mut pn, y0p, 0.0, 1.0, n)
                }
            };
            assert_path_matches(&traj, &per_path, dim, batch, p);
        }
    }
}

#[test]
fn batched_matches_per_path_bitwise_dense_system() {
    let sde = DenseCoupled;
    let (dim, batch, n) = (2usize, 9usize, 30usize);
    let aos = aos_start(dim, batch);
    let y0 = aos_to_soa(&aos, dim, batch);
    let noise = CounterGridNoise::new(5, 3, 0.0, 1.0, n);
    let opts = BatchOptions { threads: 1, chunk: 4, ..Default::default() };
    let te = integrate_batched::<BatchEulerMaruyama, _, _>(
        &sde, &noise, &y0, batch, 0.0, 1.0, n, &opts,
    )
    .expect("fault-free by construction"); // test-only unwrap: no injection here
    let tr = integrate_batched::<BatchReversibleHeun, _, _>(
        &sde, &noise, &y0, batch, 0.0, 1.0, n, &opts,
    )
    .expect("fault-free by construction"); // test-only unwrap: no injection here
    for p in 0..batch {
        let y0p = &aos[p * dim..(p + 1) * dim];
        let mut pn = noise.path(p);
        let mut s = EulerMaruyama::new(2, 3);
        let pe = integrate(&sde, &mut s, &mut pn, y0p, 0.0, 1.0, n);
        assert_path_matches(&te, &pe, dim, batch, p);
        let mut pn = noise.path(p);
        let mut s = ReversibleHeun::new(&sde, 0.0, y0p);
        let pr = integrate(&sde, &mut s, &mut pn, y0p, 0.0, 1.0, n);
        assert_path_matches(&tr, &pr, dim, batch, p);
    }
}

#[test]
fn diagonal_fast_path_matches_dense_path() {
    let inner = TanhDiagonal::new(6, 31);
    let dense = DenseWrap(&inner);
    let (dim, batch, n) = (6usize, 10usize, 20usize);
    let aos = aos_start(dim, batch);
    let y0 = aos_to_soa(&aos, dim, batch);
    let noise = CounterGridNoise::new(17, dim, 0.0, 1.0, n);
    let opts = BatchOptions::default();
    let fast = integrate_batched::<BatchReversibleHeun, _, _>(
        &inner, &noise, &y0, batch, 0.0, 1.0, n, &opts,
    )
    .expect("fault-free by construction"); // test-only unwrap: no injection here
    let slow = integrate_batched::<BatchReversibleHeun, _, _>(
        &dense, &noise, &y0, batch, 0.0, 1.0, n, &opts,
    )
    .expect("fault-free by construction"); // test-only unwrap: no injection here
    assert_eq!(fast, slow, "diagonal fast path diverged from dense path");
}

#[test]
fn results_identical_across_thread_counts_and_chunks() {
    let sde = TanhDiagonal::new(4, 3);
    let (dim, batch, n) = (4usize, 97usize, 16usize);
    let aos = aos_start(dim, batch);
    let y0 = aos_to_soa(&aos, dim, batch);
    let noise = CounterGridNoise::new(9, dim, 0.0, 1.0, n);
    let reference = integrate_batched::<BatchReversibleHeun, _, _>(
        &sde,
        &noise,
        &y0,
        batch,
        0.0,
        1.0,
        n,
        &BatchOptions { threads: 1, chunk: 8, ..Default::default() },
    )
    .expect("fault-free by construction"); // test-only unwrap: no injection here
    for threads in [2usize, 4] {
        let traj = integrate_batched::<BatchReversibleHeun, _, _>(
            &sde,
            &noise,
            &y0,
            batch,
            0.0,
            1.0,
            n,
            &BatchOptions { threads, chunk: 8, ..Default::default() },
        )
        .expect("fault-free by construction"); // test-only unwrap: no injection here
        assert_eq!(reference, traj, "threads={threads} changed the result");
    }
    for chunk in [1usize, 13, 64, 200] {
        let traj = integrate_batched::<BatchReversibleHeun, _, _>(
            &sde,
            &noise,
            &y0,
            batch,
            0.0,
            1.0,
            n,
            &BatchOptions { threads: 3, chunk, ..Default::default() },
        )
        .expect("fault-free by construction"); // test-only unwrap: no injection here
        assert_eq!(reference, traj, "chunk={chunk} changed the result");
    }
}

#[test]
fn batched_revheun_roundtrips_below_1e10() {
    let sde = TanhDiagonal::new(10, 99);
    let (dim, batch, n) = (10usize, 32usize, 100usize);
    let aos = aos_start(dim, batch);
    let y0 = aos_to_soa(&aos, dim, batch);
    let noise = CounterGridNoise::new(33, dim, 0.0, 1.0, n);
    let dt = 1.0 / n as f64;

    let mut stepper =
        <BatchReversibleHeun as neuralsde::solvers::BatchStepper>::for_chunk(&sde, 0.0, &y0, batch);
    let (z0, zh0, mu0, sigma0) = (
        stepper.z().to_vec(),
        stepper.zh().to_vec(),
        stepper.mu().to_vec(),
        stepper.sigma().to_vec(),
    );
    // Forward sweep, retaining each step's increments.
    let mut dws: Vec<Vec<f64>> = Vec::with_capacity(n);
    for k in 0..n {
        let (s, t) = (k as f64 * dt, (k + 1) as f64 * dt);
        let mut dw = vec![0.0; dim * batch];
        noise.fill_step(k, s, t, 0, batch, &mut dw);
        stepper.forward_step(&sde, s, dt, &dw);
        dws.push(dw);
    }
    // Reverse sweep with the same increments.
    for k in (0..n).rev() {
        stepper.reverse_step(&sde, (k + 1) as f64 * dt, dt, &dws[k]);
    }
    let max_diff = |a: &[f64], b: &[f64]| {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0f64, f64::max)
    };
    let err = max_diff(stepper.z(), &z0)
        .max(max_diff(stepper.zh(), &zh0))
        .max(max_diff(stepper.mu(), &mu0))
        .max(max_diff(stepper.sigma(), &sigma0));
    assert!(err < 1e-10, "batched forward∘reverse round-trip error {err}");
}

/// Batch sizes around the 4-wide SIMD unroll: below it, exactly one block,
/// one block + remainder, two blocks, and a large odd size.
const REMAINDER_BATCHES: [usize; 6] = [1, 3, 4, 7, 8, 33];

/// Run one batched solve of `sde` with stepper `which` and assert each
/// path's trajectory equals the scalar per-path solve bit-for-bit.
fn assert_batched_bitwise<S: Sde + Sync>(sde: &S, which: &str, batch: usize, n: usize) {
    let dim = Sde::dim(sde);
    let nd = Sde::noise_dim(sde);
    let aos = aos_start(dim, batch);
    let y0 = aos_to_soa(&aos, dim, batch);
    let noise = CounterGridNoise::new(77, nd, 0.0, 1.0, n);
    let opts = BatchOptions { threads: 1, chunk: batch, ..Default::default() };
    let traj = match which {
        "euler" => integrate_batched::<BatchEulerMaruyama, _, _>(
            sde, &noise, &y0, batch, 0.0, 1.0, n, &opts,
        ),
        "midpoint" => integrate_batched::<BatchMidpoint, _, _>(
            sde, &noise, &y0, batch, 0.0, 1.0, n, &opts,
        ),
        "heun" => integrate_batched::<BatchHeun, _, _>(sde, &noise, &y0, batch, 0.0, 1.0, n, &opts),
        _ => integrate_batched::<BatchReversibleHeun, _, _>(
            sde, &noise, &y0, batch, 0.0, 1.0, n, &opts,
        ),
    }
    .expect("fault-free by construction"); // test-only unwrap: no injection here
    for p in 0..batch {
        let y0p = &aos[p * dim..(p + 1) * dim];
        let mut pn = noise.path(p);
        let per_path = match which {
            "euler" => {
                let mut s = EulerMaruyama::new(dim, nd);
                integrate(sde, &mut s, &mut pn, y0p, 0.0, 1.0, n)
            }
            "midpoint" => {
                let mut s = Midpoint::new(dim, nd);
                integrate(sde, &mut s, &mut pn, y0p, 0.0, 1.0, n)
            }
            "heun" => {
                let mut s = Heun::new(dim, nd);
                integrate(sde, &mut s, &mut pn, y0p, 0.0, 1.0, n)
            }
            _ => {
                let mut s = ReversibleHeun::new(sde, 0.0, y0p);
                integrate(sde, &mut s, &mut pn, y0p, 0.0, 1.0, n)
            }
        };
        assert_path_matches(&traj, &per_path, dim, batch, p);
    }
}

#[test]
fn simd_remainder_lanes_bitwise_diagonal_all_steppers() {
    // dim 5 keeps the per-component lanes misaligned from the batch sizes;
    // every stepper must stay bit-identical to per-path integration across
    // full blocks, remainders and the scalar-only case.
    let sde = TanhDiagonal::new(5, 17);
    for &batch in &REMAINDER_BATCHES {
        for which in ["euler", "midpoint", "heun", "revheun"] {
            assert_batched_bitwise(&sde, which, batch, 12);
        }
    }
}

#[test]
fn simd_remainder_lanes_bitwise_dense_all_steppers() {
    let sde = DenseCoupled;
    for &batch in &REMAINDER_BATCHES {
        for which in ["euler", "midpoint", "heun", "revheun"] {
            assert_batched_bitwise(&sde, which, batch, 10);
        }
    }
}

#[test]
fn native_tanh_diagonal_matches_blanket_adapter() {
    // Same seed => same matrices; the hand-batched SoA mat-vec must produce
    // the exact bits the gather/scatter adapter does, for every stepper and
    // for batch sizes exercising the remainder lanes.
    let adapter = TanhDiagonal::new(6, 21);
    let native = TanhDiagonalBatch::new(6, 21);
    let (dim, n) = (6usize, 15usize);
    for &batch in &[1usize, 5, 33, 64] {
        let aos = aos_start(dim, batch);
        let y0 = aos_to_soa(&aos, dim, batch);
        let noise = CounterGridNoise::new(3, dim, 0.0, 1.0, n);
        let opts = BatchOptions { threads: 1, chunk: 16, ..Default::default() };
        macro_rules! check {
            ($stepper:ty, $label:expr) => {
                let a = integrate_batched::<$stepper, _, _>(
                    &adapter, &noise, &y0, batch, 0.0, 1.0, n, &opts,
                )
                .expect("fault-free by construction"); // test-only unwrap: no injection here
                let b = integrate_batched::<$stepper, _, _>(
                    &native, &noise, &y0, batch, 0.0, 1.0, n, &opts,
                )
                .expect("fault-free by construction"); // test-only unwrap: no injection here
                assert_eq!(a, b, "{} diverged at batch {batch}", $label);
            };
        }
        check!(BatchEulerMaruyama, "euler");
        check!(BatchMidpoint, "midpoint");
        check!(BatchHeun, "heun");
        check!(BatchReversibleHeun, "revheun");
    }
}

#[test]
fn native_dense_coupled_matches_blanket_adapter() {
    let (dim, n) = (2usize, 18usize);
    for &batch in &[1usize, 7, 33] {
        let aos = aos_start(dim, batch);
        let y0 = aos_to_soa(&aos, dim, batch);
        let noise = CounterGridNoise::new(11, 3, 0.0, 1.0, n);
        let opts = BatchOptions { threads: 1, chunk: 8, ..Default::default() };
        macro_rules! check {
            ($stepper:ty, $label:expr) => {
                let a = integrate_batched::<$stepper, _, _>(
                    &DenseCoupled, &noise, &y0, batch, 0.0, 1.0, n, &opts,
                )
                .expect("fault-free by construction"); // test-only unwrap: no injection here
                let b = integrate_batched::<$stepper, _, _>(
                    &DenseCoupledBatch, &noise, &y0, batch, 0.0, 1.0, n, &opts,
                )
                .expect("fault-free by construction"); // test-only unwrap: no injection here
                assert_eq!(a, b, "{} diverged at batch {batch}", $label);
            };
        }
        check!(BatchEulerMaruyama, "euler");
        check!(BatchMidpoint, "midpoint");
        check!(BatchHeun, "heun");
        check!(BatchReversibleHeun, "revheun");
    }
}

#[test]
fn work_stealing_results_invariant_under_skewed_chunks() {
    // Many more chunks than threads with an uneven tail: whatever schedule
    // the stealing produces, the result must equal the single-thread solve.
    let sde = TanhDiagonal::new(3, 8);
    let (dim, batch, n) = (3usize, 131usize, 12usize);
    let aos = aos_start(dim, batch);
    let y0 = aos_to_soa(&aos, dim, batch);
    let noise = CounterGridNoise::new(29, dim, 0.0, 1.0, n);
    let reference = integrate_batched::<BatchEulerMaruyama, _, _>(
        &sde,
        &noise,
        &y0,
        batch,
        0.0,
        1.0,
        n,
        &BatchOptions { threads: 1, chunk: 4, ..Default::default() },
    )
    .expect("fault-free by construction"); // test-only unwrap: no injection here
    for threads in [2usize, 3, 5, 8] {
        let traj = integrate_batched::<BatchEulerMaruyama, _, _>(
            &sde,
            &noise,
            &y0,
            batch,
            0.0,
            1.0,
            n,
            &BatchOptions { threads, chunk: 4, ..Default::default() },
        )
        .expect("fault-free by construction"); // test-only unwrap: no injection here
        assert_eq!(reference, traj, "threads={threads} changed the result");
    }
}

// ---------------------------------------------------------------------------
// f32 / 8-wide lane path.
// ---------------------------------------------------------------------------

/// Per-path starting states at `f32` precision (the same values
/// `aos_start` produces, rounded once).
fn aos_start_f32(dim: usize, batch: usize) -> Vec<f32> {
    aos_start(dim, batch).iter().map(|&v| v as f32).collect()
}

/// Serves paths `off..` of an inner [`CounterGridNoise`] at `f32` — lets a
/// batch-of-one solve see exactly the increments path `off` receives inside
/// any larger batch (the per-path reference for the f32 bitwise pins).
struct OffsetNoiseF32<'a> {
    inner: &'a CounterGridNoise,
    off: usize,
}

impl BatchNoise<f32> for OffsetNoiseF32<'_> {
    fn brownian_dim(&self) -> usize {
        <CounterGridNoise as BatchNoise<f32>>::brownian_dim(self.inner)
    }

    fn fill_step(&self, k: usize, s: f64, t: f64, p0: usize, chunk: usize, out: &mut [f32]) {
        self.inner.fill_step(k, s, t, self.off + p0, chunk, out);
    }
}

/// Run one f32 batched solve and assert each path's trajectory equals a
/// single-path f32 solve on the same noise **bit-for-bit** — the 8-wide
/// lanes' twin of the f64 per-path pins (the scalar remainder loop of the
/// kernels is the per-path reference arithmetic at this precision).
fn assert_f32_batched_bitwise<M, S>(sde: &S, batch: usize, n: usize, label: &str)
where
    M: BatchStepper<Elem = f32>,
    S: BatchSde<f32>,
{
    let dim = sde.state_dim();
    let aos = aos_start_f32(dim, batch);
    let y0 = aos_to_soa(&aos, dim, batch);
    let noise = CounterGridNoise::new(77, sde.brownian_dim(), 0.0, 1.0, n);
    // Chunk 4 exercises chunk boundaries misaligned from the 8-wide unroll.
    let opts = BatchOptions { threads: 1, chunk: 4, ..Default::default() };
    let traj = integrate_batched::<M, _, _>(sde, &noise, &y0, batch, 0.0, 1.0, n, &opts)
        .expect("fault-free by construction"); // test-only unwrap: no injection here
    let opts1 = BatchOptions { threads: 1, chunk: 1, ..Default::default() };
    for p in 0..batch {
        let y0p: Vec<f32> = (0..dim).map(|i| aos[p * dim + i]).collect();
        let pn = OffsetNoiseF32 { inner: &noise, off: p };
        let tp = integrate_batched::<M, _, _>(sde, &pn, &y0p, 1, 0.0, 1.0, n, &opts1)
            .expect("fault-free by construction"); // test-only unwrap: no injection here
        for k in 0..=n {
            for i in 0..dim {
                let a = traj[k * dim * batch + i * batch + p];
                let b = tp[k * dim + i];
                assert!(
                    a == b,
                    "{label} path {p} step {k} component {i}: batched {a:e} vs per-path {b:e}"
                );
            }
        }
    }
}

#[test]
fn f32_remainder_lanes_bitwise_diagonal_all_steppers() {
    // dim 5 keeps per-component lanes misaligned from the batch sizes;
    // remainder batches around the 8-wide unroll (below it, one block,
    // block + remainder, and a large odd size).
    let sde = TanhDiagonalBatch::new(5, 17);
    for &batch in &REMAINDER_BATCHES {
        assert_f32_batched_bitwise::<BatchEulerMaruyama<f32>, _>(&sde, batch, 12, "euler");
        assert_f32_batched_bitwise::<BatchMidpoint<f32>, _>(&sde, batch, 12, "midpoint");
        assert_f32_batched_bitwise::<BatchHeun<f32>, _>(&sde, batch, 12, "heun");
        assert_f32_batched_bitwise::<BatchReversibleHeun<f32>, _>(&sde, batch, 12, "revheun");
    }
}

#[test]
fn f32_remainder_lanes_bitwise_dense_all_steppers() {
    for &batch in &REMAINDER_BATCHES {
        let s = &DenseCoupledBatch;
        assert_f32_batched_bitwise::<BatchEulerMaruyama<f32>, _>(s, batch, 10, "euler");
        assert_f32_batched_bitwise::<BatchMidpoint<f32>, _>(s, batch, 10, "midpoint");
        assert_f32_batched_bitwise::<BatchHeun<f32>, _>(s, batch, 10, "heun");
        assert_f32_batched_bitwise::<BatchReversibleHeun<f32>, _>(s, batch, 10, "revheun");
    }
}

#[test]
fn f32_results_identical_across_thread_counts_and_chunks() {
    let sde = TanhDiagonalBatch::new(4, 3);
    let (dim, batch, n) = (4usize, 97usize, 16usize);
    let y0 = aos_to_soa(&aos_start_f32(dim, batch), dim, batch);
    let noise = CounterGridNoise::new(9, dim, 0.0, 1.0, n);
    let reference = integrate_batched::<BatchReversibleHeun<f32>, _, _>(
        &sde,
        &noise,
        &y0,
        batch,
        0.0,
        1.0,
        n,
        &BatchOptions { threads: 1, chunk: 8, ..Default::default() },
    )
    .expect("fault-free by construction"); // test-only unwrap: no injection here
    for threads in [2usize, 4] {
        let traj = integrate_batched::<BatchReversibleHeun<f32>, _, _>(
            &sde,
            &noise,
            &y0,
            batch,
            0.0,
            1.0,
            n,
            &BatchOptions { threads, chunk: 8, ..Default::default() },
        )
        .expect("fault-free by construction"); // test-only unwrap: no injection here
        assert_eq!(reference, traj, "threads={threads} changed the f32 result");
    }
    for chunk in [1usize, 13, 64, 200] {
        let traj = integrate_batched::<BatchReversibleHeun<f32>, _, _>(
            &sde,
            &noise,
            &y0,
            batch,
            0.0,
            1.0,
            n,
            &BatchOptions { threads: 3, chunk, ..Default::default() },
        )
        .expect("fault-free by construction"); // test-only unwrap: no injection here
        assert_eq!(reference, traj, "chunk={chunk} changed the f32 result");
    }
}

/// The time-dependent Ornstein–Uhlenbeck system of Appendix F.7 as a
/// **precision-generic** native batch system: one generic impl, so the f32
/// and f64 instantiations run the same token stream at their own precision.
struct OuBatchGeneric {
    rho: f64,
    kappa: f64,
    chi: f64,
}

impl<T: Lane> BatchSde<T> for OuBatchGeneric {
    fn state_dim(&self) -> usize {
        1
    }
    fn brownian_dim(&self) -> usize {
        1
    }
    fn diagonal_noise(&self) -> bool {
        true
    }
    fn drift_batch(&self, t: f64, y: &[T], out: &mut [T], batch: usize) {
        let rt = T::from_f64(self.rho * t);
        let ka = T::from_f64(self.kappa);
        for p in 0..batch {
            out[p] = rt - ka * y[p];
        }
    }
    fn diffusion_batch(&self, _t: f64, _y: &[T], out: &mut [T], batch: usize) {
        let c = T::from_f64(self.chi);
        for p in 0..batch {
            out[p] = c;
        }
    }
    fn diffusion_diag_batch(&self, _t: f64, _y: &[T], out: &mut [T], batch: usize) {
        let c = T::from_f64(self.chi);
        for p in 0..batch {
            out[p] = c;
        }
    }
}

#[test]
fn f32_and_f64_agree_on_the_ou_system_within_1e4() {
    // The f64 reversible-Heun solve of this system is pinned against the
    // closed-form OU solution in `solver_properties.rs`; here we pin the
    // cross-precision gap on the same Brownian sample (the f32 increments
    // are the rounded f64 draws): rel L∞ ≤ 1e-4 over the whole trajectory,
    // so the f32 path inherits the f64 path's accuracy up to single-
    // precision truncation.
    let sde = OuBatchGeneric { rho: 0.02, kappa: 0.1, chi: 0.4 };
    let (batch, n) = (16usize, 64usize);
    let noise = CounterGridNoise::new(91, 1, 0.0, 1.0, n);
    let y64 = vec![1.0f64; batch];
    let y32 = vec![1.0f32; batch];
    let opts = BatchOptions { threads: 1, chunk: 8, ..Default::default() };
    for which in ["euler", "revheun"] {
        // test-only unwraps below: no injection here
        let (t64, t32) = match which {
            "euler" => (
                integrate_batched::<BatchEulerMaruyama, _, _>(
                    &sde, &noise, &y64, batch, 0.0, 1.0, n, &opts,
                )
                .expect("fault-free by construction"),
                integrate_batched::<BatchEulerMaruyama<f32>, _, _>(
                    &sde, &noise, &y32, batch, 0.0, 1.0, n, &opts,
                )
                .expect("fault-free by construction"),
            ),
            _ => (
                integrate_batched::<BatchReversibleHeun, _, _>(
                    &sde, &noise, &y64, batch, 0.0, 1.0, n, &opts,
                )
                .expect("fault-free by construction"),
                integrate_batched::<BatchReversibleHeun<f32>, _, _>(
                    &sde, &noise, &y32, batch, 0.0, 1.0, n, &opts,
                )
                .expect("fault-free by construction"),
            ),
        };
        let mut worst = 0.0f64;
        for (a, b) in t64.iter().zip(&t32) {
            worst = worst.max((a - *b as f64).abs() / a.abs().max(1.0));
        }
        assert!(worst < 1e-4, "{which}: f32 vs f64 rel L∞ {worst}");
    }
}

#[test]
fn trajectory_layout_and_initial_state() {
    let sde = TanhDiagonal::new(3, 1);
    let (dim, batch, n) = (3usize, 5usize, 4usize);
    let aos = aos_start(dim, batch);
    let y0 = aos_to_soa(&aos, dim, batch);
    let noise = CounterGridNoise::new(1, dim, 0.0, 1.0, n);
    let traj = integrate_batched::<BatchEulerMaruyama, _, _>(
        &sde,
        &noise,
        &y0,
        batch,
        0.0,
        1.0,
        n,
        &BatchOptions::default(),
    )
    .expect("fault-free by construction"); // test-only unwrap: no injection here
    assert_eq!(traj.len(), (n + 1) * dim * batch);
    assert_eq!(&traj[..dim * batch], y0.as_slice(), "time 0 must be y0");
}
