//! The persistent executor's zero-allocation / zero-spawn contract, pinned
//! with a counting global allocator and the pool's spawn probe:
//!
//! * a warmed [`pool::run_tasks`] / [`pool::join2`] dispatch performs
//!   **zero** heap allocations at every fan-out width — the job registry,
//!   part queues and parking are all fixed-size or stack-resident;
//! * workers are spawned **once per process**: repeated dispatch (including
//!   full GAN training steps, whose solves and real/fake adjoint overlap
//!   all ride the same pool) never creates another thread;
//! * a warm training step's allocation count is *flat* step over step —
//!   the remaining per-step allocations are the caller-facing result
//!   buffers (`map_chunks`' result vector, trajectory outputs), not
//!   executor state, and their count must not drift.
//!
//! Everything lives in ONE `#[test]` because the global allocator and the
//! process-wide pool are shared: a concurrently running test in the same
//! binary would pollute both counters.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use neuralsde::brownian::SplitPrng;
use neuralsde::config::TrainConfig;
use neuralsde::coordinator::GanTrainer;
use neuralsde::data::ou;
use neuralsde::solvers::{pool, BatchOptions};

/// Counts every allocation and reallocation in the process.
struct CountingAlloc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn warm_executor_never_allocates_and_never_respawns() {
    // ---- Phase 1: the bare executor ------------------------------------
    // Warm every shape we are about to measure (first dispatch spawns the
    // workers; spawning allocates stacks, names, handles).
    let sink = AtomicUsize::new(0);
    let touch = |i: usize| {
        sink.fetch_add(i + 1, Ordering::Relaxed);
    };
    for &(threads, n) in &[(4usize, 1usize), (4, 8), (4, 64), (8, 512)] {
        pool::run_tasks(threads, n, &touch);
    }
    let _ = pool::join2(4, || 1usize, || 2usize);
    let spawned = pool::spawn_count();
    assert!(spawned >= 1, "warmup must have spawned pool workers");

    let before = ALLOCS.load(Ordering::SeqCst);
    for _ in 0..10 {
        for &(threads, n) in &[(4usize, 1usize), (4, 8), (4, 64), (8, 512)] {
            pool::run_tasks(threads, n, &touch);
        }
        let (a, b) = pool::join2(4, || 3usize, || 4usize);
        assert_eq!((a, b), (3, 4));
    }
    let executor_allocs = ALLOCS.load(Ordering::SeqCst) - before;
    assert_eq!(
        executor_allocs, 0,
        "warm pool dispatch must not allocate (saw {executor_allocs} over 10 rounds)"
    );
    assert_eq!(
        pool::spawn_count(),
        spawned,
        "repeated dispatch must reuse the spawned workers"
    );

    // ---- Phase 2: full GAN training steps on the same pool -------------
    let mut cfg = TrainConfig::default();
    cfg.steps = 6;
    cfg.batch = 12;
    cfg.data_size = 64;
    let mut data = ou::generate(cfg.data_size, 3, ou::OuParams::default());
    data.normalise_initial();
    let opts = BatchOptions { threads: 4, chunk: 3, ..Default::default() };
    let mut trainer = GanTrainer::new(&cfg, cfg.steps).expect("trainer").with_batch_options(opts);
    let mut rng = SplitPrng::new(5);

    // Two warmup steps: internal scratch, Adadelta state and the Brownian
    // caches reach steady capacity.
    for _ in 0..2 {
        trainer.train_step(&data, &mut rng).expect("warmup step");
    }
    let spawned_after_warm = pool::spawn_count();

    let mut per_step = Vec::with_capacity(4);
    for _ in 0..4 {
        let s0 = ALLOCS.load(Ordering::SeqCst);
        trainer.train_step(&data, &mut rng).expect("steady step");
        per_step.push(ALLOCS.load(Ordering::SeqCst) - s0);
    }
    assert_eq!(
        pool::spawn_count(),
        spawned_after_warm,
        "training steps must never spawn threads (per-call spawn/join is dead)"
    );
    // The executor contributes zero of these allocations (phase 1); what
    // remains is the caller-facing per-step result buffers, whose count is
    // shape-determined and must be flat — any drift would be a leak or a
    // regression toward per-call executor state.
    for (i, &n) in per_step.iter().enumerate() {
        assert_eq!(
            n, per_step[0],
            "warm train_step allocation count drifted at step {i}: {per_step:?}"
        );
    }
}
