#!/usr/bin/env bash
# Run every benchmark in the suite and refresh the machine-tracked
# BENCH_pr*.json trajectory files at the repo root.
#
# Usage:
#   tools/bench_all.sh            # full runs (the numbers that get committed)
#   QUICK=1 tools/bench_all.sh    # trimmed workloads; BENCH json is skipped
#
# Full runs take minutes; each bench also writes its local copy under
# rust/results/. Benches that own a BENCH_pr<N>.json write it to the repo
# root via BENCH_DIR=.. (and refuse to do so under QUICK so a smoke run
# never overwrites tracked numbers).

set -euo pipefail
cd "$(dirname "$0")/../rust"

BENCHES=(
  # hotpath_micro carries the pool/* executor-dispatch rows (persistent
  # pool vs per-call scoped spawn/join) introduced with BENCH_pr10.json.
  hotpath_micro
  # tab1_training_step owns BENCH_pr10.json: the overlap/* rows time the
  # real/fake discriminator-adjoint overlap (pool::join2) on single-chunk
  # solves — disc_adjoint_overlap is the headline ratio — alongside the
  # carried native/mixed f32_vs_f64 rows.
  tab1_training_step
  tab2_brownian_access
  tab3_clipping
  tab10_sde_solve
  # serve_throughput owns BENCH_pr9.json: uniform open-loop rows plus the
  # mixed-size packed-vs-fifo rows (per-class p50/p99 and the
  # interactive_p99_fifo_over_packed headline ratio) and the
  # diagonal-noise f32 fast-path rows (diag_over_dense_paths_per_sec).
  serve_throughput
)

for bench in "${BENCHES[@]}"; do
  echo "==> cargo bench --bench ${bench}"
  BENCH_DIR=.. cargo bench --bench "${bench}"
done

echo "==> done; tracked trajectories:"
ls -1 ../BENCH_pr*.json
