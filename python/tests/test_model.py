"""L2 model-level tests: GAN and Latent SDE losses/gradients/samples.

The strongest checks ride on the reversible Heun method's exactness: for
that solver the hand-assembled O-t-D gradient pipelines in ``model.py``
must agree with ``jax.grad`` of the corresponding end-to-end forward
computation to floating-point error.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model, nets, sdeint

jax.config.update("jax_enable_x64", True)

SPEC = model.GanSpec(data_dim=1, seq_len=8, state=6, hidden=8, noise=3,
                     init_noise=3, disc_state=5, disc_hidden=8)
B = 4


def rngs(seed):
    return np.random.default_rng(seed)


def gan_inputs(seed, dtype=jnp.float64):
    r = rngs(seed)
    n = SPEC.seq_len - 1
    theta = jnp.asarray(r.normal(size=SPEC.gen_layout().total) * 0.3, dtype)
    phi = jnp.asarray(r.normal(size=SPEC.disc_layout().total) * 0.3, dtype)
    v = jnp.asarray(r.normal(size=(B, SPEC.v)), dtype)
    ts = jnp.linspace(-0.5, 0.5, SPEC.seq_len, dtype=dtype)
    dws = jnp.asarray(r.normal(size=(n, B, SPEC.w)) * np.sqrt(1.0 / n), dtype)
    y_real = jnp.asarray(r.normal(size=(B, SPEC.seq_len, SPEC.y)), dtype)
    return theta, phi, v, ts, dws, y_real


def gen_loss_e2e(solver, theta, phi, v, ts, dws):
    """End-to-end generator loss (pure forward, for jax.grad reference)."""
    gl, dl = SPEC.gen_layout(), SPEC.disc_layout()
    gp, dp = gl.unflatten(theta), dl.unflatten(phi)
    _, _, _, y_path = model._gen_forward(SPEC, solver, gp, v, ts, dws)
    _, _, _, score = model._disc_forward(SPEC, solver, dp, y_path, ts)
    return jnp.mean(score)


def disc_loss_e2e(solver, theta, phi, v, ts, dws, y_real):
    gl, dl = SPEC.gen_layout(), SPEC.disc_layout()
    gp, dp = gl.unflatten(theta), dl.unflatten(phi)
    _, _, _, y_fake = model._gen_forward(SPEC, solver, gp, v, ts, dws)
    y_real_path = jnp.transpose(y_real, (1, 0, 2))
    _, _, _, sf = model._disc_forward(SPEC, solver, dp, y_fake, ts)
    _, _, _, sr = model._disc_forward(SPEC, solver, dp, y_real_path, ts)
    return jnp.mean(sr) - jnp.mean(sf)


def rel_err(a, b):
    a, b = np.asarray(a), np.asarray(b)
    return np.abs(a - b).sum() / max(np.abs(a).sum(), np.abs(b).sum(), 1e-300)


def test_gan_generator_grad_exact_for_revheun():
    theta, phi, v, ts, dws, _ = gan_inputs(0)
    loss, g = model.gan_generator_grad(SPEC, "reversible_heun", theta, phi, v, ts, dws)
    ref_loss = gen_loss_e2e("reversible_heun", theta, phi, v, ts, dws)
    ref_g = jax.grad(lambda th: gen_loss_e2e("reversible_heun", th, phi, v, ts, dws))(theta)
    assert abs(float(loss - ref_loss)) < 1e-10
    assert rel_err(g, ref_g) < 1e-9, rel_err(g, ref_g)


def test_gan_discriminator_grad_exact_for_revheun():
    theta, phi, v, ts, dws, y_real = gan_inputs(1)
    loss, g = model.gan_discriminator_grad(SPEC, "reversible_heun", theta, phi,
                                           v, ts, dws, y_real)
    ref_loss = disc_loss_e2e("reversible_heun", theta, phi, v, ts, dws, y_real)
    ref_g = jax.grad(
        lambda ph: disc_loss_e2e("reversible_heun", theta, ph, v, ts, dws, y_real))(phi)
    assert abs(float(loss - ref_loss)) < 1e-10
    assert rel_err(g, ref_g) < 1e-9, rel_err(g, ref_g)


@pytest.mark.parametrize("which", ["gen", "disc"])
def test_gan_grads_midpoint_biased_but_close(which):
    """Midpoint O-t-D gradients carry truncation bias: nonzero but small."""
    theta, phi, v, ts, dws, y_real = gan_inputs(2)
    if which == "gen":
        _, g = model.gan_generator_grad(SPEC, "midpoint", theta, phi, v, ts, dws)
        ref_g = jax.grad(lambda th: gen_loss_e2e("midpoint", th, phi, v, ts, dws))(theta)
    else:
        _, g = model.gan_discriminator_grad(SPEC, "midpoint", theta, phi, v,
                                            ts, dws, y_real)
        ref_g = jax.grad(
            lambda ph: disc_loss_e2e("midpoint", theta, ph, v, ts, dws, y_real))(phi)
    e = rel_err(g, ref_g)
    assert 1e-12 < e < 0.5, f"unexpected midpoint bias {e}"


def test_gan_gp_grad_runs_and_differs_from_plain():
    theta, phi, v, ts, dws, y_real = gan_inputs(3)
    l1, g1 = model.gan_discriminator_grad(SPEC, "midpoint", theta, phi, v, ts,
                                          dws, y_real)
    l2, g2 = model.gan_discriminator_grad_gp(SPEC, "midpoint", theta, phi, v,
                                             ts, dws, y_real)
    assert np.isfinite(float(l2))
    assert float(l2) != pytest.approx(float(l1))
    assert g2.shape == g1.shape


def test_gan_sample_shapes_and_pallas_consistency():
    theta, phi, v, ts, dws, _ = gan_inputs(4)
    theta32 = theta.astype(jnp.float32)
    v32, ts32, dws32 = (a.astype(jnp.float32) for a in (v, ts, dws))
    y_pallas = model.gan_sample(SPEC, "reversible_heun", theta32, v32, ts32,
                                dws32, use_pallas=True)
    y_ref = model.gan_sample(SPEC, "reversible_heun", theta32, v32, ts32,
                             dws32, use_pallas=False)
    assert y_pallas.shape == (B, SPEC.seq_len, SPEC.y)
    np.testing.assert_allclose(np.asarray(y_pallas), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)


LSPEC = model.LatentSpec(data_dim=2, seq_len=6, state=5, hidden=8, ctx=4,
                         init_noise=3)


def latent_inputs(seed, dtype=jnp.float64):
    r = rngs(seed)
    n = LSPEC.seq_len - 1
    params = jnp.asarray(r.normal(size=LSPEC.layout().total) * 0.3, dtype)
    ts = jnp.linspace(-0.5, 0.5, LSPEC.seq_len, dtype=dtype)
    dws = jnp.asarray(r.normal(size=(n, B, LSPEC.x)) * np.sqrt(1.0 / n), dtype)
    y_real = jnp.asarray(r.normal(size=(B, LSPEC.seq_len, LSPEC.y)), dtype)
    eps = jnp.asarray(r.normal(size=(B, LSPEC.v)), dtype)
    return params, ts, dws, y_real, eps


def latent_loss_e2e(solver, params_flat, ts, dws, y_real, eps):
    lay = LSPEC.layout()
    p = lay.unflatten(params_flat)
    y_real_path = jnp.transpose(y_real, (1, 0, 2))
    ctx = model._latent_context(LSPEC, p, y_real_path)
    enc = nets.mlp_apply(p, "xi", y_real_path[0])
    v_mean, v_logstd = enc[:, :LSPEC.v], jnp.clip(enc[:, LSPEC.v:], -6.0, 3.0)
    v_hat = v_mean + jnp.exp(v_logstd) * eps
    z0 = nets.mlp_apply(p, "zeta", v_hat)
    drift, diffusion = model._latent_fields(LSPEC)
    x_path, _ = sdeint.forward(solver, drift, diffusion, p, z0, ts, dws, u=ctx)
    kl_v = jnp.mean(jnp.sum(
        0.5 * (v_mean ** 2 + jnp.exp(2 * v_logstd) - 1.0) - v_logstd, axis=1))
    return model._latent_loss_from_path(LSPEC, p, x_path, ts, ctx,
                                        y_real_path, 1.0) + kl_v


def test_latent_grad_exact_for_revheun():
    params, ts, dws, y_real, eps = latent_inputs(5)
    loss, g = model.latent_grad(LSPEC, "reversible_heun", params, ts, dws,
                                y_real, eps)
    ref_loss = latent_loss_e2e("reversible_heun", params, ts, dws, y_real, eps)
    ref_g = jax.grad(
        lambda p: latent_loss_e2e("reversible_heun", p, ts, dws, y_real, eps))(params)
    assert abs(float(loss - ref_loss)) < 1e-9
    assert rel_err(g, ref_g) < 1e-8, rel_err(g, ref_g)


def test_latent_grad_midpoint_runs():
    params, ts, dws, y_real, eps = latent_inputs(6)
    loss, g = model.latent_grad(LSPEC, "midpoint", params, ts, dws, y_real, eps)
    assert np.isfinite(float(loss))
    assert np.isfinite(np.asarray(g)).all()


def test_latent_training_reduces_loss():
    """A few SGD steps on the ELBO must reduce it (end-to-end sanity)."""
    params, ts, dws, y_real, eps = latent_inputs(7)
    p = params
    losses = []
    for k in range(30):
        loss, g = model.latent_grad(LSPEC, "reversible_heun", p, ts, dws,
                                    y_real, eps)
        losses.append(float(loss))
        p = p - 0.02 * g / (jnp.abs(g).max() + 1e-8)
    assert losses[-1] < losses[0], losses[:3] + losses[-3:]


def test_latent_sample_shape():
    params, ts, dws, y_real, eps = latent_inputs(8)
    params32 = params.astype(jnp.float32)
    v = eps.astype(jnp.float32)
    y = model.latent_sample(LSPEC, "reversible_heun", params32, v,
                            ts.astype(jnp.float32), dws.astype(jnp.float32))
    assert y.shape == (B, LSPEC.seq_len, LSPEC.y)


def test_gradient_error_revheun_exact_midpoint_not():
    spec = model.GradErrSpec(state=8, noise=4, hidden=6, batch=4)
    r = rngs(9)
    params = jnp.asarray(r.normal(size=spec.layout().total) * 0.4)
    z0 = jnp.asarray(r.normal(size=(spec.b, spec.x)))[:4]
    n = 16
    ts = jnp.linspace(0.0, 1.0, n + 1)
    dws = jnp.asarray(r.normal(size=(n, 4, spec.w)) * np.sqrt(1.0 / n))
    o_gz, o_gp, d_gz, d_gp = model.gradient_error(spec, "reversible_heun",
                                                  params, z0, ts, dws)
    assert rel_err(o_gp, d_gp) < 1e-11
    assert rel_err(o_gz, d_gz) < 1e-11
    o_gz, o_gp, d_gz, d_gp = model.gradient_error(spec, "midpoint", params,
                                                  z0, ts, dws)
    assert rel_err(o_gp, d_gp) > 1e-8
