"""L2 correctness for the steppers and backward passes.

The decisive test is ``test_revheun_backward_matches_autodiff``: the
optimise-then-discretise gradients from Algorithm 2 must equal the
discretise-then-optimise gradients (``jax.grad`` through the forward scan)
to floating-point roundoff — the paper's central claim (Figure 2). The
midpoint/Heun adjoints must instead show an O(h) gap that shrinks with the
step size.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import sdeint
from compile.kernels import ref

jax.config.update("jax_enable_x64", True)


# A small neural SDE in the style of the paper's Appendix F.5 test problem.
E, D, B, H = 6, 3, 4, 8


def make_params(seed, dtype=jnp.float64):
    r = np.random.default_rng(seed)

    def t(*shape):
        return jnp.asarray(r.normal(size=shape) * 0.4, dtype)

    return dict(fw1=t(1 + E, H), fb1=t(H), fw2=t(H, E), fb2=t(E),
                gw1=t(1 + E, H), gb1=t(H), gw2=t(H, E * D), gb2=t(E * D))


def drift(p, t, z, u):
    x = jnp.concatenate([jnp.full((z.shape[0], 1), t, z.dtype), z], axis=1)
    return ref.mlp2_lipswish(x, p["fw1"], p["fb1"], p["fw2"], p["fb2"], "sigmoid")


def diffusion(p, t, z, u):
    x = jnp.concatenate([jnp.full((z.shape[0], 1), t, z.dtype), z], axis=1)
    out = ref.mlp2_lipswish(x, p["gw1"], p["gb1"], p["gw2"], p["gb2"], "sigmoid")
    return out.reshape(z.shape[0], E, D)


def problem(seed=0, n=16, dtype=jnp.float64):
    r = np.random.default_rng(seed + 100)
    z0 = jnp.asarray(r.normal(size=(B, E)), dtype)
    ts = jnp.linspace(0.0, 1.0, n + 1, dtype=dtype)
    dws = jnp.asarray(r.normal(size=(n, B, D)) * np.sqrt(1.0 / n), dtype)
    return z0, ts, dws


def loss_fn(solver, params, z0, ts, dws):
    path, _ = sdeint.forward(solver, drift, diffusion, params, z0, ts, dws)
    # Loss touches the terminal state AND an intermediate observation, to
    # exercise the per-path-point cotangents.
    return jnp.sum(path[-1] ** 2) + jnp.sum(jnp.abs(path[ts.shape[0] // 2]))


def otd_grads(solver, params, z0, ts, dws):
    """Optimise-then-discretise gradients via the backward passes."""
    path, final_state = sdeint.forward(solver, drift, diffusion, params, z0, ts, dws)
    cots = jax.grad(
        lambda pth: jnp.sum(pth[-1] ** 2) + jnp.sum(jnp.abs(pth[ts.shape[0] // 2]))
    )(path)
    return sdeint.backward(solver, drift, diffusion, params, final_state, ts,
                           dws, cots)


@pytest.mark.parametrize("solver", sdeint.SOLVERS)
def test_forward_shapes(solver):
    params = make_params(0)
    z0, ts, dws = problem(0)
    path, final = sdeint.forward(solver, drift, diffusion, params, z0, ts, dws)
    assert path.shape == (17, B, E)
    np.testing.assert_allclose(np.asarray(path[0]), np.asarray(z0))


def test_solvers_agree_to_leading_order():
    params = make_params(1)
    z0, ts, dws = problem(1, n=256)
    ends = {}
    for solver in sdeint.SOLVERS:
        path, _ = sdeint.forward(solver, drift, diffusion, params, z0, ts, dws)
        ends[solver] = np.asarray(path[-1])
    for s in ("midpoint", "heun"):
        err = np.max(np.abs(ends["reversible_heun"] - ends[s]))
        assert err < 5e-2, f"{s}: {err}"


def test_revheun_forward_is_algebraically_reversible():
    params = make_params(2)
    z0, ts, dws = problem(2)
    _, (z, zh, mu, sig) = sdeint.forward("reversible_heun", drift, diffusion,
                                         params, z0, ts, dws)
    # Manually run Algorithm 2's reverse steps back to t0.
    n = dws.shape[0]
    for k in range(n - 1, -1, -1):
        dt = ts[k + 1] - ts[k]
        zh0 = 2 * z - zh - mu * dt - sdeint.bmv(sig, dws[k])
        mu0 = drift(params, ts[k], zh0, None)
        sig0 = diffusion(params, ts[k], zh0, None)
        z = z - 0.5 * (mu0 + mu) * dt - sdeint.bmv(0.5 * (sig0 + sig), dws[k])
        zh, mu, sig = zh0, mu0, sig0
    np.testing.assert_allclose(np.asarray(z), np.asarray(z0), rtol=1e-9, atol=1e-9)
    np.testing.assert_allclose(np.asarray(zh), np.asarray(z0), rtol=1e-9, atol=1e-9)


def test_revheun_backward_matches_autodiff():
    """THE property: O-t-D == D-t-O for the reversible Heun method, to
    floating-point error (~1e-13 relative in f64)."""
    params = make_params(3)
    z0, ts, dws = problem(3)
    gz0, gp, gdws, _ = otd_grads("reversible_heun", params, z0, ts, dws)
    ref_gp, ref_gz0, ref_gdws = jax.grad(
        lambda p, z, w: loss_fn("reversible_heun", p, z, ts, w),
        argnums=(0, 1, 2))(params, z0, dws)
    np.testing.assert_allclose(np.asarray(gz0), np.asarray(ref_gz0),
                               rtol=1e-10, atol=1e-12)
    for k in params:
        np.testing.assert_allclose(np.asarray(gp[k]), np.asarray(ref_gp[k]),
                                   rtol=1e-9, atol=1e-12, err_msg=k)
    np.testing.assert_allclose(np.asarray(gdws), np.asarray(ref_gdws),
                               rtol=1e-9, atol=1e-12)


def rel_l1(a, b):
    num = sum(float(jnp.sum(jnp.abs(a[k] - b[k]))) for k in a)
    den = max(sum(float(jnp.sum(jnp.abs(a[k]))) for k in a),
              sum(float(jnp.sum(jnp.abs(b[k]))) for k in b))
    return num / den


@pytest.mark.parametrize("solver", ["midpoint", "heun"])
def test_adjoint_backward_error_shrinks_with_h(solver):
    """Midpoint/Heun O-t-D gradients are biased; the bias must fall as the
    step size falls (the downward-sloping curves of Figure 2)."""
    params = make_params(4)
    errs = []
    for n in (8, 64):
        z0, ts, dws = problem(4, n=n)
        _, gp, _, _ = otd_grads(solver, params, z0, ts, dws)
        ref_gp = jax.grad(lambda p: loss_fn(solver, p, z0, ts, dws))(params)
        errs.append(rel_l1(gp, ref_gp))
    assert errs[0] > 1e-6, f"suspiciously exact at coarse h: {errs}"
    assert errs[1] < errs[0], f"error did not shrink: {errs}"


def test_revheun_error_is_fp_noise_vs_adjoint_bias():
    """At the same step size, reversible Heun's gradient error must sit many
    orders of magnitude below midpoint's (the Figure-2 separation)."""
    params = make_params(5)
    z0, ts, dws = problem(5, n=16)
    _, gp_rh, _, _ = otd_grads("reversible_heun", params, z0, ts, dws)
    ref_rh = jax.grad(lambda p: loss_fn("reversible_heun", p, z0, ts, dws))(params)
    _, gp_mp, _, _ = otd_grads("midpoint", params, z0, ts, dws)
    ref_mp = jax.grad(lambda p: loss_fn("midpoint", p, z0, ts, dws))(params)
    e_rh = rel_l1(gp_rh, ref_rh)
    e_mp = rel_l1(gp_mp, ref_mp)
    assert e_rh < 1e-11, f"revheun gradient error {e_rh}"
    assert e_mp > 1e4 * e_rh, f"separation too small: revheun={e_rh} midpoint={e_mp}"


def test_exogenous_input_threading():
    """Fields may consume the per-time input u (the Latent SDE context)."""
    params = make_params(6)
    z0, ts, dws = problem(6, n=8)
    u = jnp.ones((9, B, 2)) * jnp.arange(9.0)[:, None, None]

    def drift_u(p, t, z, uk):
        return drift(p, t, z, None) + 0.01 * jnp.sum(uk, axis=1, keepdims=True)

    path_u, fin = sdeint.forward("reversible_heun", drift_u, diffusion, params,
                                 z0, ts, dws, u=u)
    path_0, _ = sdeint.forward("reversible_heun", drift_u, diffusion, params,
                               z0, ts, dws, u=jnp.zeros_like(u))
    assert float(jnp.max(jnp.abs(path_u - path_0))) > 1e-4
    # Backward with u runs and matches autodiff.
    cots = jnp.zeros_like(path_u).at[-1].set(1.0)
    gz0, gp, _, _ = sdeint.backward_revheun(drift_u, diffusion, params, fin, ts,
                                         dws, cots, u=u)
    ref_gz0 = jax.grad(lambda z: jnp.sum(
        sdeint.forward("reversible_heun", drift_u, diffusion, params, z, ts,
                       dws, u=u)[0][-1]))(z0)
    np.testing.assert_allclose(np.asarray(gz0), np.asarray(ref_gz0),
                               rtol=1e-9, atol=1e-11)
