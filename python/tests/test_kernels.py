"""L1 correctness: Pallas kernels vs the pure-jnp oracles in ``ref.py``.

Hypothesis sweeps shapes and dtypes; every property asserts allclose between
the kernel (interpret mode) and the oracle, plus a handful of analytic
sanity checks (Lipschitz bound of LipSwish, reversibility of the fused
update).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import mlp_field, ref, revheun

jax.config.update("jax_enable_x64", True)


def rng(seed):
    return np.random.default_rng(seed)


dims = st.integers(min_value=1, max_value=24)
batches = st.integers(min_value=1, max_value=300)
dtypes = st.sampled_from([np.float32, np.float64])
finals = st.sampled_from(["none", "tanh", "sigmoid"])


def tol(dtype):
    return dict(rtol=2e-5, atol=2e-5) if dtype == np.float32 else dict(rtol=1e-12, atol=1e-12)


@settings(max_examples=40, deadline=None)
@given(b=batches, d_in=dims, d_h=dims, d_out=dims, final=finals, dtype=dtypes,
       seed=st.integers(0, 2**31))
def test_mlp_kernel_matches_ref(b, d_in, d_h, d_out, final, dtype, seed):
    r = rng(seed)
    x = r.normal(size=(b, d_in)).astype(dtype)
    w1 = r.normal(size=(d_in, d_h)).astype(dtype) * 0.5
    b1 = r.normal(size=(d_h,)).astype(dtype) * 0.1
    w2 = r.normal(size=(d_h, d_out)).astype(dtype) * 0.5
    b2 = r.normal(size=(d_out,)).astype(dtype) * 0.1
    got = mlp_field.mlp2_lipswish(x, w1, b1, w2, b2, final=final)
    want = ref.mlp2_lipswish(x, w1, b1, w2, b2, final=final)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **tol(dtype))
    assert got.dtype == dtype


@settings(max_examples=10, deadline=None)
@given(b=batches, block=st.sampled_from([1, 7, 64, 128, 256]))
def test_mlp_kernel_block_size_invariant(b, block):
    """Output must not depend on the block size (padding is stripped)."""
    r = rng(b * 1000 + block)
    x = r.normal(size=(b, 5)).astype(np.float32)
    w1 = r.normal(size=(5, 9)).astype(np.float32)
    b1 = np.zeros(9, np.float32)
    w2 = r.normal(size=(9, 3)).astype(np.float32)
    b2 = np.zeros(3, np.float32)
    base = mlp_field.mlp2_lipswish(x, w1, b1, w2, b2, block=128)
    got = mlp_field.mlp2_lipswish(x, w1, b1, w2, b2, block=block)
    # f32 GEMMs may reassociate differently per block shape: allow a few ulp.
    np.testing.assert_allclose(np.asarray(got), np.asarray(base), rtol=3e-5, atol=3e-5)


@settings(max_examples=40, deadline=None)
@given(b=batches, d=dims, dtype=dtypes, seed=st.integers(0, 2**31),
       dt=st.floats(min_value=1e-4, max_value=2.0))
def test_revheun_update_matches_ref(b, d, dtype, seed, dt):
    r = rng(seed)
    args = [r.normal(size=(b, d)).astype(dtype) for _ in range(6)]
    dt = dtype(dt)
    gz, gzh = revheun.revheun_update(*args, dt)
    wz, wzh = ref.revheun_update(*args, dt)
    np.testing.assert_allclose(np.asarray(gz), np.asarray(wz), **tol(dtype))
    np.testing.assert_allclose(np.asarray(gzh), np.asarray(wzh), **tol(dtype))


def test_lipswish_is_one_lipschitz():
    """Numerical check that sup |ρ'(x)| <= 1 (the Section-5 requirement)."""
    x = jnp.linspace(-20.0, 20.0, 200001, dtype=jnp.float64)
    g = jax.vmap(jax.grad(lambda v: ref.lipswish(v)))(x)
    assert float(jnp.max(jnp.abs(g))) <= 1.0 + 1e-9


def test_lipswish_smooth_at_zero():
    g2 = jax.grad(jax.grad(lambda v: ref.lipswish(v)))(0.0)
    assert np.isfinite(float(g2))


def test_revheun_update_is_reversible_linear_algebra():
    """The fused update, inverted per Algorithm 2, returns the old state."""
    r = rng(7)
    z, zh, mu, sdw, mun, sdwn = [r.normal(size=(4, 3)) for _ in range(6)]
    dt = 0.125
    zn, zhn = ref.revheun_update(z, zh, mu, sdw, mun, sdwn, dt)
    # Inverse (with the *next* fields known, as the backward pass has them):
    zh_rec = 2.0 * zn - zhn - mun * dt - sdwn
    z_rec = zn - 0.5 * (mu + mun) * dt - 0.5 * (sdw + sdwn)
    np.testing.assert_allclose(np.asarray(zh_rec), zh, rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(np.asarray(z_rec), z, rtol=1e-12, atol=1e-12)


def test_batched_matvec_matches_loop():
    r = rng(3)
    mat = r.normal(size=(5, 4, 3))
    vec = r.normal(size=(5, 3))
    got = np.asarray(ref.batched_matvec(jnp.asarray(mat), jnp.asarray(vec)))
    for b in range(5):
        np.testing.assert_allclose(got[b], mat[b] @ vec[b], rtol=1e-12)


@pytest.mark.parametrize("block", [32, 128, 512])
def test_vmem_footprint_under_budget(block):
    """The perf-estimate helper: every configuration we lower stays far
    below the 16 MiB VMEM budget."""
    bytes_ = mlp_field.vmem_footprint_bytes(block, 64, 64, 64)
    assert bytes_ < 16 * 2**20 * 0.1


def test_mlp_rejects_unknown_final():
    x = jnp.zeros((2, 3), jnp.float32)
    w1 = jnp.zeros((3, 4), jnp.float32)
    b1 = jnp.zeros(4, jnp.float32)
    w2 = jnp.zeros((4, 2), jnp.float32)
    b2 = jnp.zeros(2, jnp.float32)
    with pytest.raises(ValueError):
        mlp_field.mlp2_lipswish(x, w1, b1, w2, b2, final="relu")
