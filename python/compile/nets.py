"""Layer-2 network definitions and the flat-parameter contract with Rust.

Training state lives in Rust as one flat ``f32`` vector per network; this
module defines the canonical layout (mirrored by ``rust/src/nn``'s
``ParamLayout``) and the unflatten/apply functions used inside the lowered
executables.

Every MLP here is the paper's shape: one hidden layer, LipSwish activation
(Appendix F.2: "the LipSwish activation function was used throughout"),
optional bounded final nonlinearity.
"""

import jax.numpy as jnp

from .kernels import mlp_field, ref


class LayoutBuilder:
    """Accumulates (name, shape, fan_in, kind) entries with offsets."""

    def __init__(self):
        self.entries = []
        self.total = 0

    def add(self, name, shape, fan_in, kind):
        size = 1
        for d in shape:
            size *= d
        self.entries.append(
            dict(name=name, shape=list(shape), offset=self.total,
                 fan_in=int(fan_in), kind=kind)
        )
        self.total += size
        return self

    def manifest(self):
        """JSON-ready layout list (consumed by rust ParamLayout)."""
        return self.entries

    def unflatten(self, flat):
        """Flat vector -> dict of named arrays."""
        out = {}
        for e in self.entries:
            size = 1
            for d in e["shape"]:
                size *= d
            out[e["name"]] = flat[e["offset"]:e["offset"] + size].reshape(e["shape"])
        return out


def add_mlp(layout, prefix, in_dim, hidden, out_dim):
    """Register a 2-layer MLP's tensors."""
    layout.add(f"{prefix}.w1", (in_dim, hidden), in_dim, "weight")
    layout.add(f"{prefix}.b1", (hidden,), in_dim, "bias")
    layout.add(f"{prefix}.w2", (hidden, out_dim), hidden, "weight")
    layout.add(f"{prefix}.b2", (out_dim,), hidden, "bias")
    return layout


def add_affine(layout, prefix, in_dim, out_dim):
    """Register an affine map's tensors (the readout ℓ_θ)."""
    layout.add(f"{prefix}.w", (in_dim, out_dim), in_dim, "weight")
    layout.add(f"{prefix}.b", (out_dim,), in_dim, "bias")
    return layout


def mlp_apply(params, prefix, x, final="none", use_pallas=False):
    """Apply a registered MLP. ``use_pallas=True`` routes through the
    Layer-1 kernel (forward-only paths; reverse-mode AD does not traverse
    ``pallas_call``, so differentiated paths use the jnp oracle — the two
    are allclose-tested in ``test_kernels.py``)."""
    w1, b1 = params[f"{prefix}.w1"], params[f"{prefix}.b1"]
    w2, b2 = params[f"{prefix}.w2"], params[f"{prefix}.b2"]
    if use_pallas:
        return mlp_field.mlp2_lipswish(x, w1, b1, w2, b2, final=final)
    return ref.mlp2_lipswish(x, w1, b1, w2, b2, final=final)


def affine_apply(params, prefix, x):
    """Apply a registered affine map."""
    return x @ params[f"{prefix}.w"] + params[f"{prefix}.b"]


def with_time(t, x):
    """Concatenate a scalar time onto each batch row: ``[B, d] -> [B, d+1]``."""
    b = x.shape[0]
    tcol = jnp.full((b, 1), t, dtype=x.dtype)
    return jnp.concatenate([tcol, x], axis=1)


# ---------------------------------------------------------------------------
# Model hyperparameter bundles
# ---------------------------------------------------------------------------


class GanSpec:
    """SDE-GAN dimensions (scaled-down Appendix F.7 defaults)."""

    def __init__(self, data_dim=1, seq_len=32, state=16, hidden=32, noise=4,
                 init_noise=4, disc_state=16, disc_hidden=32):
        self.y = data_dim
        self.seq_len = seq_len
        self.x = state
        self.h = hidden
        self.w = noise
        self.v = init_noise
        self.dh = disc_state
        self.dhh = disc_hidden

    def gen_layout(self):
        lb = LayoutBuilder()
        add_mlp(lb, "zeta", self.v, self.h, self.x)  # ζ_θ: V -> X_0
        add_mlp(lb, "mu", 1 + self.x, self.h, self.x)  # μ_θ(t, X)
        add_mlp(lb, "sigma", 1 + self.x, self.h, self.x * self.w)  # σ_θ(t, X)
        add_affine(lb, "ell", self.x, self.y)  # ℓ_θ: X -> Y
        return lb

    def disc_layout(self):
        lb = LayoutBuilder()
        add_mlp(lb, "xi", 1 + self.y, self.dhh, self.dh)  # ξ_φ(t0, Y_0)
        add_mlp(lb, "f", 1 + self.dh, self.dhh, self.dh)  # f_φ(t, H)
        add_mlp(lb, "g", 1 + self.dh, self.dhh, self.dh * self.y)  # g_φ(t, H)
        lb.add("m", (self.dh,), self.dh, "other")  # m_φ readout
        return lb

    def hyper(self):
        return dict(y=self.y, seq_len=self.seq_len, x=self.x, h=self.h,
                    w=self.w, v=self.v, dh=self.dh, dhh=self.dhh)


class LatentSpec:
    """Latent SDE dimensions (scaled-down Appendix F.4 defaults).

    Diffusion is diagonal (as in torchsde's Latent SDE) so the KL term's
    ``σ^{-1}`` is well-defined.
    """

    def __init__(self, data_dim=2, seq_len=24, state=16, hidden=32,
                 ctx=16, init_noise=4):
        self.y = data_dim
        self.seq_len = seq_len
        self.x = state
        self.h = hidden
        self.c = ctx
        self.v = init_noise

    def layout(self):
        """Single joint layout: (θ = generative) + (φ = inference)."""
        lb = LayoutBuilder()
        # θ: prior drift, shared diffusion, initial map, readout.
        add_mlp(lb, "zeta", self.v, self.h, self.x)
        add_mlp(lb, "mu", 1 + self.x, self.h, self.x)
        add_mlp(lb, "sigma", 1 + self.x, self.h, self.x)  # diagonal
        add_affine(lb, "ell", self.x, self.y)
        # φ: encoder to (mean, logstd) of V̂; posterior drift ν; GRU context.
        add_mlp(lb, "xi", self.y, self.h, 2 * self.v)
        add_mlp(lb, "nu", 1 + self.x + self.c, self.h, self.x)
        # Reversed GRU over observations: input y, state c.
        lb.add("gru.wi", (self.y, 3 * self.c), self.y, "weight")
        lb.add("gru.wh", (self.c, 3 * self.c), self.c, "weight")
        lb.add("gru.b", (3 * self.c,), self.y, "bias")
        return lb

    def hyper(self):
        return dict(y=self.y, seq_len=self.seq_len, x=self.x, h=self.h,
                    c=self.c, v=self.v)


def sigma_diag(params, t, x, use_pallas=False):
    """Diagonal diffusion for the Latent SDE: positive, bounded away from 0
    so the KL's σ^{-1} stays finite: ``0.05 + 0.9·sigmoid(·)``."""
    raw = mlp_apply(params, "sigma", with_time(t, x), final="sigmoid",
                    use_pallas=use_pallas)
    return 0.05 + 0.9 * raw


def gru_cell(params, y, h):
    """One (reversed-direction) GRU step: input ``y [B, y]``, state
    ``h [B, c]`` -> new state."""
    c = h.shape[1]
    gi = y @ params["gru.wi"] + params["gru.b"]
    gh = h @ params["gru.wh"]
    r = ref.sigmoid(gi[:, :c] + gh[:, :c])
    z = ref.sigmoid(gi[:, c:2 * c] + gh[:, c:2 * c])
    n = jnp.tanh(gi[:, 2 * c:] + r * gh[:, 2 * c:])
    return (1.0 - z) * n + z * h
