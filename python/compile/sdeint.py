"""Layer-2 SDE/CDE integration: forward scans and the two backward passes.

Everything the paper studies happens here:

* :func:`forward` — fixed-step solve of ``dZ = μ(t,Z,u) dt + σ(t,Z,u)·dW``
  by the reversible Heun method (Algorithm 1), midpoint, or Heun. The same
  code integrates the generator SDE (``dW`` = Brownian increments from the
  Rust Brownian Interval), the discriminator CDE (``dW`` = path increments
  ``ΔY``), and the Latent SDE posterior (``u`` = GRU context).

* :func:`backward_revheun` — the **exact** optimise-then-discretise
  backward pass (Algorithm 2): algebraically reverse the state, then apply
  the VJP of the local forward step. Gradients match
  discretise-then-optimise to floating-point roundoff (Figure 2).

* :func:`backward_adjoint` — the classical continuous-adjoint backward pass
  used with midpoint/Heun: solve the combined state+adjoint SDE (equation
  (6)) *backwards in time with the same solver*, re-integrating the state
  and therefore incurring the truncation error the paper eliminates.

Conventions: ``ts [N+1]`` grid times; ``dws [N, B, d]`` increments;
``u [N+1, B, k]`` optional per-time exogenous input (zeros if unused);
fields have signature ``drift(params, t, z, u) -> [B, e]`` and
``diffusion(params, t, z, u) -> [B, e, d]``. Cotangents are supplied for
*every* path point (``[N+1, B, e]``) so losses may depend on intermediate
observations, as the GAN/Latent losses do.
"""

import jax
import jax.numpy as jnp

from .kernels import ref, revheun as revheun_kernel

SOLVERS = ("reversible_heun", "midpoint", "heun")

bmv = ref.batched_matvec


def _tree_axpy(alpha, x, y):
    """y + alpha * x over pytrees."""
    return jax.tree_util.tree_map(lambda a, b: b + alpha * a, x, y)


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _fwd_step_revheun(drift, diffusion, params, z, zh, mu, sig, t0, t1, dw, u,
                      use_pallas=False):
    """One Algorithm-1 step. Returns the new ``(z, zh, mu, sig)``."""
    dt = t1 - t0
    sdw = bmv(sig, dw)
    zh1 = 2.0 * z - zh + mu * dt + sdw
    mu1 = drift(params, t1, zh1, u)
    sig1 = diffusion(params, t1, zh1, u)
    sdw1 = bmv(sig1, dw)
    if use_pallas:
        z1, zh1 = revheun_kernel.revheun_update(z, zh, mu, sdw, mu1, sdw1, dt)
    else:
        z1, zh1 = ref.revheun_update(z, zh, mu, sdw, mu1, sdw1, dt)
    return z1, zh1, mu1, sig1


def _fwd_step_midpoint(drift, diffusion, params, z, t0, t1, dw, u0, u1):
    dt = t1 - t0
    tm = t0 + 0.5 * dt
    um = 0.5 * (u0 + u1)
    zm = z + 0.5 * dt * drift(params, t0, z, u0) \
        + bmv(diffusion(params, t0, z, u0), 0.5 * dw)
    return z + dt * drift(params, tm, zm, um) + bmv(diffusion(params, tm, zm, um), dw)


def _fwd_step_heun(drift, diffusion, params, z, t0, t1, dw, u0, u1):
    dt = t1 - t0
    f0 = drift(params, t0, z, u0)
    g0 = diffusion(params, t0, z, u0)
    zp = z + dt * f0 + bmv(g0, dw)
    f1 = drift(params, t1, zp, u1)
    g1 = diffusion(params, t1, zp, u1)
    return z + 0.5 * dt * (f0 + f1) + bmv(0.5 * (g0 + g1), dw)


def forward(solver, drift, diffusion, params, z0, ts, dws, u=None,
            use_pallas=False):
    """Integrate forward; returns ``(path [N+1, B, e], final_state)``.

    ``final_state`` is ``(z, zh, mu, sig)`` for reversible Heun (everything
    the backward pass needs — nothing else is retained, the paper's memory
    win) and ``z`` for the other solvers.
    """
    n = dws.shape[0]
    if u is None:
        u = jnp.zeros((n + 1, z0.shape[0], 0), z0.dtype)

    if solver == "reversible_heun":
        mu0 = drift(params, ts[0], z0, u[0])
        sig0 = diffusion(params, ts[0], z0, u[0])

        def step(carry, inp):
            z, zh, mu, sig = carry
            t0, t1, dw, u1 = inp
            out = _fwd_step_revheun(drift, diffusion, params, z, zh, mu, sig,
                                    t0, t1, dw, u1, use_pallas=use_pallas)
            return out, out[0]

        carry, zs = jax.lax.scan(
            step, (z0, z0, mu0, sig0), (ts[:-1], ts[1:], dws, u[1:]))
        path = jnp.concatenate([z0[None], zs], axis=0)
        return path, carry

    if solver == "midpoint":
        step_fn = _fwd_step_midpoint
    elif solver == "heun":
        step_fn = _fwd_step_heun
    else:
        raise ValueError(f"unknown solver {solver!r}")

    def step(z, inp):
        t0, t1, dw, u0, u1 = inp
        z1 = step_fn(drift, diffusion, params, z, t0, t1, dw, u0, u1)
        return z1, z1

    zend, zs = jax.lax.scan(step, z0, (ts[:-1], ts[1:], dws, u[:-1], u[1:]))
    path = jnp.concatenate([z0[None], zs], axis=0)
    return path, zend


# ---------------------------------------------------------------------------
# Backward: exact (reversible Heun, Algorithm 2)
# ---------------------------------------------------------------------------


def backward_revheun(drift, diffusion, params, final_state, ts, dws,
                     cotangents, u=None):
    """Exact O-t-D backward pass.

    ``final_state = (z_N, ẑ_N, μ_N, σ_N)`` from :func:`forward`;
    ``cotangents [N+1, B, e]`` = ``∂L/∂z_k`` for every path point.

    Returns ``(gz0, gparams, gdws, gus)`` where ``gz0 [B, e]`` is
    ``∂L/∂z_0``, ``gparams`` matches the ``params`` pytree, ``gdws
    [N, B, d]`` are cotangents w.r.t. the driving increments (used to chain
    the discriminator CDE's gradient back into the generated path), and
    ``gus [N+1, B, k]`` are cotangents w.r.t. the exogenous input (the
    Latent SDE's context path).
    """
    n = dws.shape[0]
    zN = final_state[0]
    if u is None:
        u = jnp.zeros((n + 1, zN.shape[0], 0), zN.dtype)
    gparams0 = jax.tree_util.tree_map(jnp.zeros_like, params)

    def fwd_local(z, zh, mu, sig, p, t0, t1, dw, u1):
        return _fwd_step_revheun(drift, diffusion, p, z, zh, mu, sig,
                                 t0, t1, dw, u1)

    def step(carry, inp):
        (z1, zh1, mu1, sig1, gz, gzh, gmu, gsig, gp) = carry
        t0, t1, dw, u0, u1, cot = inp
        dt = t1 - t0
        # Algorithm 2, "reverse step" — closed form, no fixed point.
        zh0 = 2.0 * z1 - zh1 - mu1 * dt - bmv(sig1, dw)
        mu0 = drift(params, t0, zh0, u0)
        sig0 = diffusion(params, t0, zh0, u0)
        z0 = z1 - 0.5 * (mu0 + mu1) * dt - bmv(0.5 * (sig0 + sig1), dw)
        # Algorithm 2, "local forward" + "local backward": VJP of the step.
        _, vjp = jax.vjp(
            lambda z, zh, mu, sig, p, dwv, uu: fwd_local(z, zh, mu, sig, p, t0, t1, dwv, uu),
            z0, zh0, mu0, sig0, params, dw, u1)
        gz0, gzh0, gmu0, gsig0, gp_inc, gdw, gu1 = vjp((gz, gzh, gmu, gsig))
        gz0 = gz0 + cot
        gp = jax.tree_util.tree_map(jnp.add, gp, gp_inc)
        return (z0, zh0, mu0, sig0, gz0, gzh0, gmu0, gsig0, gp), (gdw, gu1)

    init = (final_state[0], final_state[1], final_state[2], final_state[3],
            cotangents[n], jnp.zeros_like(zN),
            jnp.zeros_like(final_state[2]), jnp.zeros_like(final_state[3]),
            gparams0)
    carry, (gdws, gu_steps) = jax.lax.scan(
        step, init,
        (ts[:-1], ts[1:], dws, u[:-1], u[1:], cotangents[:-1]),
        reverse=True)
    (z0, _zh0, _mu0, _sig0, gz, gzh, gmu, gsig, gp) = carry
    # The initial carry was (z0, z0, μ(t0, z0), σ(t0, z0)): fold the ẑ/μ/σ
    # cotangents back onto z0, the parameters, and u[0].
    _, vjp0 = jax.vjp(
        lambda z, p, uu: (z, drift(p, ts[0], z, uu), diffusion(p, ts[0], z, uu)),
        z0, params, u[0])
    gz_extra, gp0, gu0 = vjp0((gzh, gmu, gsig))
    gz_total = gz + gz_extra
    gp = jax.tree_util.tree_map(jnp.add, gp, gp0)
    gus = jnp.concatenate([gu0[None], gu_steps], axis=0)
    return gz_total, gp, gdws, gus


# ---------------------------------------------------------------------------
# Backward: continuous adjoint (midpoint / Heun — inexact)
# ---------------------------------------------------------------------------


def backward_adjoint(solver, drift, diffusion, params, z_final, ts, dws,
                     cotangents, u=None):
    """Classical O-t-D backward pass (equation (6)).

    The augmented state ``(z, a, gθ)`` is stepped *backwards in time with
    the same solver* (negated ``dt``/``dW``), re-integrating ``z`` — whose
    truncation error is what pollutes these gradients (Figure 2, the
    midpoint/Heun curves). Returns ``(gz0, gparams, gdws)``.
    """
    if solver == "midpoint":
        base_step = _fwd_step_midpoint
    elif solver == "heun":
        base_step = _fwd_step_heun
    else:
        raise ValueError(f"adjoint backward needs midpoint/heun, got {solver!r}")
    n = dws.shape[0]
    if u is None:
        u = jnp.zeros((n + 1, z_final.shape[0], 0), z_final.dtype)
    gparams0 = jax.tree_util.tree_map(jnp.zeros_like, params)

    # Augmented fields over state (z, a, gθ): equation (6). The drift and
    # diffusion VJPs are evaluated by jax.vjp on the user fields.
    def aug_drift(t, state, uk):
        z, a, _ = state
        mu, vjp = jax.vjp(lambda zz, pp: drift(pp, t, zz, uk), z, params)
        da, dp = vjp(a)
        return mu, -da, jax.tree_util.tree_map(jnp.negative, dp)

    def aug_diff_prod(t, state, dw, uk):
        z, a, _ = state
        sd, vjp = jax.vjp(lambda zz, pp: bmv(diffusion(pp, t, zz, uk), dw), z, params)
        da, dp = vjp(a)
        return sd, -da, jax.tree_util.tree_map(jnp.negative, dp)

    def add(s, inc, scale=1.0):
        z, a, g = s
        dz, da, dg = inc
        return (z + scale * dz, a + scale * da, _tree_axpy(scale, dg, g))

    def step_aug(t1, t0, state, dw, u1, u0):
        """One backward step t1 -> t0 (dt and dw enter negated)."""
        dt = t0 - t1  # negative
        ndw = -dw
        if solver == "midpoint":
            tm = t1 + 0.5 * dt
            um = 0.5 * (u0 + u1)
            half = add(add(state, aug_drift(t1, state, u1), 0.5 * dt),
                       aug_diff_prod(t1, state, 0.5 * ndw, u1))
            out = add(add(state, aug_drift(tm, half, um), dt),
                      aug_diff_prod(tm, half, ndw, um))
        else:  # heun
            f1 = aug_drift(t1, state, u1)
            g1 = aug_diff_prod(t1, state, ndw, u1)
            pred = add(add(state, f1, dt), g1)
            f0 = aug_drift(t0, pred, u0)
            g0 = aug_diff_prod(t0, pred, ndw, u0)
            out = add(add(state, jax.tree_util.tree_map(lambda x, y: 0.5 * (x + y), f1, f0), dt),
                      add((jnp.zeros_like(state[0]), jnp.zeros_like(state[1]),
                           jax.tree_util.tree_map(jnp.zeros_like, state[2])),
                          jax.tree_util.tree_map(lambda x, y: 0.5 * (x + y), g1, g0)))
        return out

    def step(carry, inp):
        t0, t1, dw, u0, u1, cot = inp
        # Cotangents w.r.t. dw and u, consistent to the method's order:
        # aᵀ·∂(step increment)/∂(dw, u) evaluated at the right endpoint.
        z1, a1, _ = carry
        dt = t1 - t0
        _, vjp_in = jax.vjp(
            lambda dwv, uu: drift(params, t1, z1, uu) * dt
            + bmv(diffusion(params, t1, z1, uu), dwv),
            dw, u1)
        gdw, gu1 = vjp_in(a1)
        state = step_aug(t1, t0, carry, dw, u1, u0)
        z, a, g = state
        state = (z, a + cot, g)
        return state, (gdw, gu1)

    init = (z_final, cotangents[n], gparams0)
    carry, (gdws, gu_steps) = jax.lax.scan(
        step, init, (ts[:-1], ts[1:], dws, u[:-1], u[1:], cotangents[:-1]),
        reverse=True)
    z0, a0, gp = carry
    gus = jnp.concatenate([jnp.zeros_like(gu_steps[:1]), gu_steps], axis=0)
    return a0, gp, gdws, gus


# ---------------------------------------------------------------------------
# Unified entry point
# ---------------------------------------------------------------------------


def backward(solver, drift, diffusion, params, final_state, ts, dws,
             cotangents, u=None):
    """Dispatch to the exact (reversible Heun) or adjoint backward pass."""
    if solver == "reversible_heun":
        return backward_revheun(drift, diffusion, params, final_state, ts,
                                dws, cotangents, u)
    return backward_adjoint(solver, drift, diffusion, params, final_state,
                            ts, dws, cotangents, u)
