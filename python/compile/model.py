"""Layer-2 models: the SDE-GAN (Kidger et al. 2021, Section 2.2), the
Latent SDE (Li et al. 2020), and the Figure-2 gradient-error test problem.

Each public ``*_grad`` / ``*_sample`` function below is an AOT entry point:
``aot.py`` lowers it once per (dataset, solver) configuration to HLO text
and the Rust coordinator calls it per training step. All gradients flow
through the **optimise-then-discretise** backward passes of
:mod:`.sdeint` — exact for the reversible Heun method, truncation-biased
for midpoint (which is precisely the comparison the paper's training tables
report).

Shapes: ``theta``/``phi`` are flat f32 vectors matching the layouts in
:mod:`.nets`; ``v [B, v]`` initial noise; ``dws [N, B, w]`` Brownian
increments (from the Rust Brownian Interval); ``y_real [B, L, y]`` a data
batch; ``ts [L]`` the (normalised) observation grid, with one solver step
per observation interval, as in the paper's experiments.
"""

import jax
import jax.numpy as jnp

from . import nets, sdeint
from .nets import GanSpec, LatentSpec  # noqa: F401  (re-export for callers)


# ---------------------------------------------------------------------------
# SDE-GAN
# ---------------------------------------------------------------------------


def _gen_fields(spec, use_pallas=False):
    def drift(p, t, z, u):
        return nets.mlp_apply(p, "mu", nets.with_time(t, z), use_pallas=use_pallas)

    def diffusion(p, t, z, u):
        out = nets.mlp_apply(p, "sigma", nets.with_time(t, z), final="tanh",
                             use_pallas=use_pallas)
        return out.reshape(z.shape[0], spec.x, spec.w)

    return drift, diffusion


def _disc_fields(spec, use_pallas=False):
    def drift(p, t, h, u):
        return nets.mlp_apply(p, "f", nets.with_time(t, h), final="tanh",
                              use_pallas=use_pallas)

    def diffusion(p, t, h, u):
        out = nets.mlp_apply(p, "g", nets.with_time(t, h), final="tanh",
                             use_pallas=use_pallas)
        return out.reshape(h.shape[0], spec.dh, spec.y)

    return drift, diffusion


def _gen_forward(spec, solver, gp, v, ts, dws, use_pallas=False):
    """ζ then the generator SDE solve; returns (x_path, final_state, y_path)."""
    z0 = nets.mlp_apply(gp, "zeta", v, use_pallas=use_pallas)
    drift, diffusion = _gen_fields(spec, use_pallas)
    x_path, fin = sdeint.forward(solver, drift, diffusion, gp, z0, ts, dws,
                                 use_pallas=use_pallas)
    y_path = nets.affine_apply(gp, "ell", x_path)  # [L, B, y]
    return z0, x_path, fin, y_path


def _disc_forward(spec, solver, dp, y_path, ts, use_pallas=False):
    """Neural CDE discriminator over a path: returns (h_path, final, score).

    ``y_path [L, B, y]``; the CDE is driven by the increments ΔY — the same
    machinery as the SDE solve with ``dws = ΔY`` (equation (2)).
    """
    dys = y_path[1:] - y_path[:-1]  # [N, B, y]
    h0 = nets.mlp_apply(dp, "xi", nets.with_time(ts[0], y_path[0]),
                        use_pallas=use_pallas)
    drift, diffusion = _disc_fields(spec, use_pallas)
    h_path, fin = sdeint.forward(solver, drift, diffusion, dp, h0, ts, dys,
                                 use_pallas=use_pallas)
    hT = h_path[-1] if solver != "reversible_heun" else fin[0]
    score = hT @ dp["m"]  # [B]
    return h0, h_path, fin, score


def _disc_backward(spec, solver, dp, y_path, ts, h_path, fin, hT_cot):
    """Backward through the CDE; returns (gφ pytree, cotangent on y_path)."""
    dys = y_path[1:] - y_path[:-1]
    drift, diffusion = _disc_fields(spec)
    cots = jnp.zeros_like(h_path).at[-1].set(hT_cot)
    final_state = fin if solver == "reversible_heun" else (
        fin if not isinstance(fin, tuple) else fin)
    gh0, gphi, gdys, _ = sdeint.backward(solver, drift, diffusion, dp,
                                      final_state, ts, dys, cots)
    # Chain ΔY cotangents onto path points: ΔY_k = Y_{k+1} − Y_k.
    y_cot = jnp.zeros_like(y_path)
    y_cot = y_cot.at[1:].add(gdys)
    y_cot = y_cot.at[:-1].add(-gdys)
    # Initial condition h0 = ξ(t0, Y_0).
    _, vjp = jax.vjp(
        lambda p, y0: nets.mlp_apply(p, "xi", nets.with_time(ts[0], y0)),
        dp, y_path[0])
    gphi_xi, gy0 = vjp(gh0)
    gphi = jax.tree_util.tree_map(jnp.add, gphi, gphi_xi)
    y_cot = y_cot.at[0].add(gy0)
    return gphi, y_cot


def gan_generator_grad(spec, solver, theta, phi, v, ts, dws):
    """One generator training step's loss and gradient (O-t-D throughout).

    Returns ``(loss_g, grad_theta_flat)``. The generator minimises
    ``E[F_φ(Y_fake)]`` (equation (3))."""
    gl, dl = spec.gen_layout(), spec.disc_layout()
    gp = gl.unflatten(theta)
    dp = dl.unflatten(phi)
    b = v.shape[0]
    z0, x_path, fin, y_path = _gen_forward(spec, solver, gp, v, ts, dws)
    _, h_path, hfin, score = _disc_forward(spec, solver, dp, y_path, ts)
    loss_g = jnp.mean(score)
    # dL/dH_T = m / B.
    hT_cot = jnp.broadcast_to(dp["m"][None, :], (b, spec.dh)) / b
    _, y_cot = _disc_backward(spec, solver, dp, y_path, ts, h_path, hfin, hT_cot)
    # Through the affine readout ℓ: Y = X @ w + b.
    x_cot = jnp.einsum("lby,xy->lbx", y_cot, gp["ell.w"])
    g_ellw = jnp.einsum("lbx,lby->xy", x_path, y_cot)
    g_ellb = jnp.sum(y_cot, axis=(0, 1))
    # Backward through the generator SDE.
    drift, diffusion = _gen_fields(spec)
    gz0, gtheta, _, _ = sdeint.backward(solver, drift, diffusion, gp, fin, ts,
                                     dws, x_cot)
    # Through ζ.
    _, vjp = jax.vjp(lambda p: nets.mlp_apply(p, "zeta", v), gp)
    (gtheta_zeta,) = vjp(gz0)
    gtheta = jax.tree_util.tree_map(jnp.add, gtheta, gtheta_zeta)
    gtheta["ell.w"] = gtheta["ell.w"] + g_ellw
    gtheta["ell.b"] = gtheta["ell.b"] + g_ellb
    return loss_g, _flatten(gl, gtheta)


def gan_discriminator_grad(spec, solver, theta, phi, v, ts, dws, y_real):
    """One discriminator step: maximise ``E[F(fake)] − E[F(real)]``, i.e.
    minimise its negation. Returns ``(loss_d, grad_phi_flat)``.

    ``y_real [B, L, y]`` is transposed internally to the path layout."""
    gl, dl = spec.gen_layout(), spec.disc_layout()
    gp = gl.unflatten(theta)
    dp = dl.unflatten(phi)
    b = v.shape[0]
    _, _, _, y_fake = _gen_forward(spec, solver, gp, v, ts, dws)
    y_real_path = jnp.transpose(y_real, (1, 0, 2))  # [L, B, y]
    _, hf_path, hf_fin, score_f = _disc_forward(spec, solver, dp, y_fake, ts)
    _, hr_path, hr_fin, score_r = _disc_forward(spec, solver, dp, y_real_path, ts)
    loss_d = jnp.mean(score_r) - jnp.mean(score_f)
    # Fake side: d loss_d / dH_T^f = -m/B; real side: +m/B.
    m_over_b = jnp.broadcast_to(dp["m"][None, :], (b, spec.dh)) / b
    gphi_f, _ = _disc_backward(spec, solver, dp, y_fake, ts, hf_path, hf_fin,
                               -m_over_b)
    gphi_r, _ = _disc_backward(spec, solver, dp, y_real_path, ts, hr_path,
                               hr_fin, m_over_b)
    gphi = jax.tree_util.tree_map(jnp.add, gphi_f, gphi_r)
    # m readout: d loss_d/dm = mean(h_T^r) − mean(h_T^f).
    hf_T = hf_fin[0] if solver == "reversible_heun" else hf_path[-1]
    hr_T = hr_fin[0] if solver == "reversible_heun" else hr_path[-1]
    gphi["m"] = gphi["m"] + jnp.mean(hr_T, axis=0) - jnp.mean(hf_T, axis=0)
    return loss_d, _flatten(dl, gphi)


def gan_discriminator_grad_gp(spec, solver, theta, phi, v, ts, dws, y_real,
                              gp_weight=10.0):
    """Discriminator step with **gradient penalty** (the Table-11 baseline,
    Gulrajani et al. 2017): a double backward through the CDE solve,
    implemented discretise-then-optimise (``jax.grad`` through the scan; see
    DESIGN.md §4 — the favourable version of the baseline)."""
    gl, dl = spec.gen_layout(), spec.disc_layout()
    gp_ = gl.unflatten(theta)
    b = v.shape[0]
    _, _, _, y_fake = _gen_forward(spec, solver, gp_, v, ts, dws)
    y_real_path = jnp.transpose(y_real, (1, 0, 2))

    def disc_score(phi_flat, y_path):
        dp = dl.unflatten(phi_flat)
        _, _, _, score = _disc_forward(spec, solver, dp, y_path, ts)
        return jnp.mean(score)

    def loss(phi_flat):
        base = disc_score(phi_flat, y_real_path) - disc_score(phi_flat, y_fake)
        # Penalty at interpolates between real and fake paths.
        alpha = 0.5
        y_mid = alpha * y_real_path + (1 - alpha) * y_fake
        g_y = jax.grad(lambda yp: disc_score(phi_flat, yp))(y_mid)
        gnorm = jnp.sqrt(jnp.sum(g_y ** 2, axis=(0, 2)) * b + 1e-12)
        return base + gp_weight * jnp.mean((gnorm - 1.0) ** 2)

    loss_d, gphi_flat = jax.value_and_grad(loss)(phi)
    return loss_d, gphi_flat


def gan_sample(spec, solver, theta, v, ts, dws, use_pallas=True):
    """Generate ``[B, L, y]`` samples (forward-only → Pallas kernels)."""
    gl = spec.gen_layout()
    gp = gl.unflatten(theta)
    _, _, _, y_path = _gen_forward(spec, solver, gp, v, ts, dws,
                                   use_pallas=use_pallas)
    return jnp.transpose(y_path, (1, 0, 2))


# ---------------------------------------------------------------------------
# Latent SDE
# ---------------------------------------------------------------------------


def _latent_context(spec, p, y_real_path):
    """Reversed GRU over observations: ctx[k] summarises y[k:]."""

    def step(h, yk):
        h1 = nets.gru_cell(p, yk, h)
        return h1, h1

    b = y_real_path.shape[1]
    h0 = jnp.zeros((b, spec.c), y_real_path.dtype)
    _, ctx_rev = jax.lax.scan(step, h0, y_real_path[::-1])
    return ctx_rev[::-1]  # [L, B, c]


def _latent_fields(spec):
    def drift(p, t, z, u):
        inp = jnp.concatenate([nets.with_time(t, z), u], axis=1)
        return nets.mlp_apply(p, "nu", inp)

    def diffusion(p, t, z, u):
        diag = nets.sigma_diag(p, t, z)
        return jax.vmap(jnp.diag)(diag)

    return drift, diffusion


def _latent_loss_from_path(spec, p, x_path, ts, ctx, y_real_path, kl_scale):
    """ELBO pieces that are functions of the solved path (equation (4))."""
    y_hat = nets.affine_apply(p, "ell", x_path)  # [L, B, y]
    recon = jnp.mean(jnp.sum((y_hat - y_real_path) ** 2, axis=(0, 2)))
    dt = ts[1] - ts[0]

    def kl_rate(t, x, u):
        prior = nets.mlp_apply(p, "mu", nets.with_time(t, x))
        post = nets.mlp_apply(p, "nu",
                              jnp.concatenate([nets.with_time(t, x), u], axis=1))
        sig = nets.sigma_diag(p, t, x)
        return 0.5 * jnp.sum(((prior - post) / sig) ** 2, axis=1)

    rates = jax.vmap(kl_rate)(ts, x_path, ctx)  # [L, B]
    kl_path = jnp.mean(jnp.sum(rates[:-1], axis=0) * dt)
    return recon + kl_scale * kl_path


def latent_grad(spec, solver, params_flat, ts, dws, y_real, eps, kl_scale=1.0):
    """One Latent SDE training step (θ and φ jointly, Adam in Rust).

    ``eps [B, v]`` is the reparameterisation noise for V̂. Returns
    ``(loss, grad_flat)``; the backward solve is O-t-D per ``solver``.
    """
    lay = spec.layout()
    p = lay.unflatten(params_flat)
    y_real_path = jnp.transpose(y_real, (1, 0, 2))
    ctx = _latent_context(spec, p, y_real_path)

    # Encoder / initial state.
    enc = nets.mlp_apply(p, "xi", y_real_path[0])
    v_mean, v_logstd = enc[:, :spec.v], jnp.clip(enc[:, spec.v:], -6.0, 3.0)
    v_hat = v_mean + jnp.exp(v_logstd) * eps
    z0 = nets.mlp_apply(p, "zeta", v_hat)

    drift, diffusion = _latent_fields(spec)
    x_path, fin = sdeint.forward(solver, drift, diffusion, p, z0, ts, dws, u=ctx)

    kl_v = jnp.mean(jnp.sum(
        0.5 * (v_mean ** 2 + jnp.exp(2 * v_logstd) - 1.0) - v_logstd, axis=1))

    loss_path, (path_cot, direct_gp, ctx_cot) = jax.value_and_grad(
        lambda xp, pp, cc: _latent_loss_from_path(spec, pp, xp, ts, cc,
                                                  y_real_path, kl_scale),
        argnums=(0, 1, 2))(x_path, p, ctx)

    gz0, gp_solve, _, gu_solve = sdeint.backward(solver, drift, diffusion, p, fin, ts,
                                       dws, path_cot, u=ctx)
    gp_total = jax.tree_util.tree_map(jnp.add, direct_gp, gp_solve)
    ctx_cot = ctx_cot + gu_solve  # the context also feeds the solve's drift

    # Chain z0 → ζ → (v̂) → encoder ξ, plus the kl_v term, plus ctx → GRU.
    def head(pp):
        enc_ = nets.mlp_apply(pp, "xi", y_real_path[0])
        m_, ls_ = enc_[:, :spec.v], jnp.clip(enc_[:, spec.v:], -6.0, 3.0)
        vh = m_ + jnp.exp(ls_) * eps
        z0_ = nets.mlp_apply(pp, "zeta", vh)
        klv = jnp.mean(jnp.sum(
            0.5 * (m_ ** 2 + jnp.exp(2 * ls_) - 1.0) - ls_, axis=1))
        ctx_ = _latent_context(spec, pp, y_real_path)
        return z0_, klv, ctx_

    _, vjp = jax.vjp(head, p)
    (gp_head,) = vjp((gz0, jnp.asarray(1.0, z0.dtype), ctx_cot))
    gp_total = jax.tree_util.tree_map(jnp.add, gp_total, gp_head)
    loss = loss_path + kl_v
    return loss, _flatten(lay, gp_total)


def latent_sample(spec, solver, params_flat, v, ts, dws, use_pallas=True):
    """Sample from the *prior* generative SDE: ``dX = μ_θ dt + σ_θ ∘ dW``."""
    lay = spec.layout()
    p = lay.unflatten(params_flat)
    z0 = nets.mlp_apply(p, "zeta", v, use_pallas=use_pallas)

    def drift(pp, t, z, u):
        return nets.mlp_apply(pp, "mu", nets.with_time(t, z),
                              use_pallas=use_pallas)

    def diffusion(pp, t, z, u):
        return jax.vmap(jnp.diag)(nets.sigma_diag(pp, t, z, use_pallas=use_pallas))

    x_path, _ = sdeint.forward(solver, drift, diffusion, p, z0, ts, dws,
                               use_pallas=use_pallas)
    y_path = nets.affine_apply(p, "ell", x_path)
    return jnp.transpose(y_path, (1, 0, 2))


# ---------------------------------------------------------------------------
# Figure 2: gradient-error test problem
# ---------------------------------------------------------------------------


class GradErrSpec:
    """The Appendix-F.5 test problem: X ∈ R^32, W ∈ R^16, hidden width 8,
    LipSwish MLPs with sigmoid finals, batch 32."""

    def __init__(self, state=32, noise=16, hidden=8, batch=32):
        self.x = state
        self.w = noise
        self.h = hidden
        self.b = batch

    def layout(self):
        lb = nets.LayoutBuilder()
        nets.add_mlp(lb, "f", 1 + self.x, self.h, self.x)
        nets.add_mlp(lb, "g", 1 + self.x, self.h, self.x * self.w)
        return lb

    def hyper(self):
        return dict(x=self.x, w=self.w, h=self.h, b=self.b)


def _graderr_fields(spec):
    def drift(p, t, z, u):
        return nets.mlp_apply(p, "f", nets.with_time(t, z), final="sigmoid")

    def diffusion(p, t, z, u):
        out = nets.mlp_apply(p, "g", nets.with_time(t, z), final="sigmoid")
        return out.reshape(z.shape[0], spec.x, spec.w)

    return drift, diffusion


def gradient_error(spec, solver, params_flat, z0, ts, dws):
    """Compute O-t-D and D-t-O gradients of ``L = Σ X_T`` on the test
    problem; returns ``(otd_gz0, otd_gtheta, dto_gz0, dto_gtheta)``.

    Lowered in f64 so the reversible-Heun error floor is the paper's ~1e-16,
    not f32's ~1e-7."""
    lay = spec.layout()
    p = lay.unflatten(params_flat)
    drift, diffusion = _graderr_fields(spec)

    def fwd_loss(pp, z, w):
        path, _ = sdeint.forward(solver, drift, diffusion, pp, z, ts, w)
        return jnp.sum(path[-1])

    # O-t-D.
    path, fin = sdeint.forward(solver, drift, diffusion, p, z0, ts, dws)
    cots = jnp.zeros_like(path).at[-1].set(1.0)
    gz0, gp, _, _ = sdeint.backward(solver, drift, diffusion, p, fin, ts, dws, cots)
    # D-t-O reference.
    ref_gp, ref_gz0 = jax.grad(fwd_loss, argnums=(0, 1))(p, z0, dws)
    return gz0, _flatten(lay, gp), ref_gz0, _flatten(lay, ref_gp)


# ---------------------------------------------------------------------------


def _flatten(layout, tree):
    """Flatten a named-parameter dict back to the layout's vector order."""
    parts = [tree[e["name"]].reshape(-1) for e in layout.entries]
    return jnp.concatenate(parts)
