"""Build-time Python package: Layer-1 Pallas kernels, the Layer-2 JAX model,
and the AOT lowering driver. Never imported at runtime — `make artifacts`
runs it once and the Rust coordinator consumes the HLO text it emits."""
