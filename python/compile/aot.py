"""AOT lowering: JAX entry points → HLO text + manifest.json.

Run once by ``make artifacts``; afterwards Python is never needed. Each
entry point of :mod:`.model` is lowered per (dataset, solver) configuration
to **HLO text** (NOT ``.serialize()`` — jax ≥ 0.5 emits protos with 64-bit
instruction ids that the xla crate's xla_extension 0.5.1 rejects; the text
parser reassigns ids and round-trips cleanly — see /opt/xla-example).

The manifest records, for every executable, its input/output shapes and,
for every model, the flat-parameter layout (consumed by ``rust/src/nn``)
and the hyperparameters baked at lowering time.

Usage: ``python -m compile.aot --out ../artifacts [--quick]``
(``--quick`` lowers a reduced set for CI smoke tests).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402

# ---------------------------------------------------------------------------
# Configurations (scaled-down Appendix-F hyperparameters; see DESIGN.md §4)
# ---------------------------------------------------------------------------

BATCH = 64
EVAL_BATCH = 256

GAN_SPECS = {
    # dataset -> GanSpec (paper: OU len 32; weights len 50; widths 32/32).
    "ou": model.GanSpec(data_dim=1, seq_len=32, state=16, hidden=32, noise=4,
                        init_noise=4, disc_state=16, disc_hidden=32),
    "weights": model.GanSpec(data_dim=1, seq_len=50, state=16, hidden=32,
                             noise=4, init_noise=4, disc_state=16,
                             disc_hidden=32),
}

LATENT_SPECS = {
    # paper: air quality, bivariate, len 24, widths 84/63 (we use 32/16).
    "air": model.LatentSpec(data_dim=2, seq_len=24, state=16, hidden=32,
                            ctx=16, init_noise=4),
}

TRAIN_SOLVERS = ("reversible_heun", "midpoint")

#: Figure-2 sweep: step sizes 2^0 … 2^-10 over T = 1.
GRADERR_NS = (1, 4, 16, 64, 256, 1024)
GRADERR_SOLVERS = ("reversible_heun", "midpoint", "heun")
GRADERR_SPEC = model.GradErrSpec(state=32, noise=16, hidden=8, batch=32)


def to_hlo_text(fn, in_specs):
    """Lower ``fn`` at the given ShapeDtypeStructs and emit HLO text."""
    lowered = jax.jit(fn).lower(*in_specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def dtype_name(d):
    return {"float32": "f32", "float64": "f64"}[jnp.dtype(d).name]


class Emitter:
    def __init__(self, out_dir):
        self.out_dir = out_dir
        self.execs = {}
        self.models = {}

    def emit(self, name, fn, in_specs, in_names):
        """Lower and write one executable; record it in the manifest."""
        print(f"  lowering {name} ...", flush=True)
        out_specs = jax.eval_shape(fn, *in_specs)
        leaves = jax.tree_util.tree_leaves(out_specs)
        text = to_hlo_text(fn, in_specs)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        self.execs[name] = {
            "file": fname,
            "inputs": [
                {"name": n, "shape": list(s.shape), "dtype": dtype_name(s.dtype)}
                for n, s in zip(in_names, in_specs)
            ],
            "outputs": [
                {"name": f"out{i}", "shape": list(s.shape),
                 "dtype": dtype_name(s.dtype)}
                for i, s in enumerate(leaves)
            ],
        }

    def add_model(self, name, gen_layout, disc_layout, hyper):
        self.models[name] = {
            "gen_layout": gen_layout.manifest() if gen_layout else [],
            "disc_layout": disc_layout.manifest() if disc_layout else [],
            "hyper": hyper,
        }

    def write_manifest(self):
        path = os.path.join(self.out_dir, "manifest.json")
        with open(path, "w") as f:
            json.dump({"version": 1, "executables": self.execs,
                       "models": self.models}, f, indent=1, sort_keys=True)
        print(f"wrote {path}: {len(self.execs)} executables, "
              f"{len(self.models)} models")


def emit_gan(em, ds, s, quick):
    L, n = s.seq_len, s.seq_len - 1
    gl, dl = s.gen_layout(), s.disc_layout()
    em.add_model(f"gan_{ds}", gl, dl,
                 dict(batch=BATCH, eval_batch=EVAL_BATCH, **s.hyper(),
                      gen_params=gl.total, disc_params=dl.total))
    f32 = jnp.float32
    ts_spec = spec((L,), f32)
    solvers = TRAIN_SOLVERS if not quick else ("reversible_heun",)
    for solver in solvers:
        em.emit(
            f"gan_{ds}_{solver}_gen_grad",
            lambda th, ph, v, ts, dws, _s=s, _sol=solver:
                model.gan_generator_grad(_s, _sol, th, ph, v, ts, dws),
            [spec((gl.total,), f32), spec((dl.total,), f32),
             spec((BATCH, s.v), f32), ts_spec, spec((n, BATCH, s.w), f32)],
            ["theta", "phi", "v", "ts", "dws"])
        em.emit(
            f"gan_{ds}_{solver}_disc_grad",
            lambda th, ph, v, ts, dws, yr, _s=s, _sol=solver:
                model.gan_discriminator_grad(_s, _sol, th, ph, v, ts, dws, yr),
            [spec((gl.total,), f32), spec((dl.total,), f32),
             spec((BATCH, s.v), f32), ts_spec, spec((n, BATCH, s.w), f32),
             spec((BATCH, L, s.y), f32)],
            ["theta", "phi", "v", "ts", "dws", "y_real"])
        em.emit(
            f"gan_{ds}_{solver}_sample",
            lambda th, v, ts, dws, _s=s, _sol=solver:
                model.gan_sample(_s, _sol, th, v, ts, dws),
            [spec((gl.total,), f32), spec((EVAL_BATCH, s.v), f32), ts_spec,
             spec((n, EVAL_BATCH, s.w), f32)],
            ["theta", "v", "ts", "dws"])
    if ds == "ou" and not quick:
        # The Table-11 gradient-penalty baseline (midpoint only, as in the
        # paper — revheun's raison d'être is avoiding this entirely).
        em.emit(
            "gan_ou_midpoint_disc_grad_gp",
            lambda th, ph, v, ts, dws, yr, _s=s:
                model.gan_discriminator_grad_gp(_s, "midpoint", th, ph, v,
                                                ts, dws, yr),
            [spec((gl.total,), f32), spec((dl.total,), f32),
             spec((BATCH, s.v), f32), ts_spec, spec((n, BATCH, s.w), f32),
             spec((BATCH, L, s.y), f32)],
            ["theta", "phi", "v", "ts", "dws", "y_real"])


def emit_latent(em, ds, s, quick):
    L, n = s.seq_len, s.seq_len - 1
    lay = s.layout()
    em.add_model(f"latent_{ds}", lay, None,
                 dict(batch=BATCH, eval_batch=EVAL_BATCH, **s.hyper(),
                      params=lay.total))
    f32 = jnp.float32
    ts_spec = spec((L,), f32)
    solvers = TRAIN_SOLVERS if not quick else ("reversible_heun",)
    for solver in solvers:
        em.emit(
            f"latent_{ds}_{solver}_grad",
            lambda p, ts, dws, yr, eps, _s=s, _sol=solver:
                model.latent_grad(_s, _sol, p, ts, dws, yr, eps),
            [spec((lay.total,), f32), ts_spec, spec((n, BATCH, s.x), f32),
             spec((BATCH, L, s.y), f32), spec((BATCH, s.v), f32)],
            ["params", "ts", "dws", "y_real", "eps"])
        em.emit(
            f"latent_{ds}_{solver}_sample",
            lambda p, v, ts, dws, _s=s, _sol=solver:
                model.latent_sample(_s, _sol, p, v, ts, dws),
            [spec((lay.total,), f32), spec((EVAL_BATCH, s.v), f32), ts_spec,
             spec((n, EVAL_BATCH, s.x), f32)],
            ["params", "v", "ts", "dws"])


def emit_graderr(em, quick):
    s = GRADERR_SPEC
    lay = s.layout()
    em.add_model("graderr", lay, None, dict(**s.hyper(), params=lay.total))
    f64 = jnp.float64
    ns = GRADERR_NS if not quick else (4, 16)
    solvers = GRADERR_SOLVERS if not quick else ("reversible_heun", "midpoint")
    for n in ns:
        for solver in solvers:
            em.emit(
                f"graderr_{solver}_n{n}",
                lambda p, z0, ts, dws, _sol=solver:
                    model.gradient_error(s, _sol, p, z0, ts, dws),
                [spec((lay.total,), f64), spec((s.b, s.x), f64),
                 spec((n + 1,), f64), spec((n, s.b, s.w), f64)],
                ["params", "z0", "ts", "dws"])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="reduced artifact set (CI smoke)")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    em = Emitter(args.out)
    for ds, s in GAN_SPECS.items():
        if args.quick and ds != "ou":
            continue
        emit_gan(em, ds, s, args.quick)
    for ds, s in LATENT_SPECS.items():
        emit_latent(em, ds, s, args.quick)
    emit_graderr(em, args.quick)
    em.write_manifest()


if __name__ == "__main__":
    main()
