"""Layer-1 Pallas kernels (build-time only; lowered into the HLO artifacts).

* :mod:`.mlp_field` — fused LipSwish-MLP vector-field evaluation;
* :mod:`.revheun`   — fused reversible-Heun state update;
* :mod:`.ref`       — pure-jnp oracles for both (the pytest ground truth).
"""

from . import ref  # noqa: F401
from .mlp_field import mlp2_lipswish  # noqa: F401
from .revheun import revheun_update  # noqa: F401
