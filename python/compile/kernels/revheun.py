"""Layer-1 Pallas kernel: fused reversible-Heun state update.

The linear part of Algorithm 1 — given the cached and freshly-evaluated
vector-field values, advance ``(z, ẑ)``:

``ẑ' = 2z − ẑ + μ Δt + σΔW``
``z' = z + ½(μ + μ') Δt + ½(σΔW + σ'ΔW)``

Six ``[B, d]`` reads, two ``[B, d]`` writes, ~8 flops/element — purely
bandwidth-bound, so the win is fusing what would otherwise be ~10 separate
HLO elementwise ops (and their HBM round-trips) into one pass. Blocked over
the batch like :mod:`.mlp_field`.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

#: Elementwise kernel: bigger blocks amortise grid overhead.
DEFAULT_BLOCK = 256


def _kernel(z_ref, zh_ref, mu_ref, sdw_ref, mun_ref, sdwn_ref, dt_ref,
            zn_ref, zhn_ref):
    z = z_ref[...]
    zh = zh_ref[...]
    mu = mu_ref[...]
    sdw = sdw_ref[...]
    dt = dt_ref[0]
    zhn_ref[...] = 2.0 * z - zh + mu * dt + sdw
    zn_ref[...] = z + 0.5 * (mu + mun_ref[...]) * dt + 0.5 * (sdw + sdwn_ref[...])


@functools.partial(jax.jit, static_argnames=("block", "use_pallas"))
def revheun_update(z, zh, mu, sdw, mu_next, sdw_next, dt,
                   block=DEFAULT_BLOCK, use_pallas=True):
    """Fused update; semantics match :func:`compile.kernels.ref.revheun_update`.

    All array args are ``[B, d]``; ``dt`` is a scalar (traced, so one
    lowered artifact serves every step size).
    """
    if not use_pallas:
        return ref.revheun_update(z, zh, mu, sdw, mu_next, sdw_next, dt)
    b, d = z.shape
    blk = min(block, max(b, 1))
    pad = (-b) % blk
    args = (z, zh, mu, sdw, mu_next, sdw_next)
    if pad:
        zpad = jnp.zeros((pad, d), z.dtype)
        args = tuple(jnp.concatenate([a, zpad], axis=0) for a in args)
    n_blocks = args[0].shape[0] // blk
    dt_arr = jnp.reshape(jnp.asarray(dt, z.dtype), (1,))
    spec = pl.BlockSpec((blk, d), lambda i: (i, 0))
    z_next, zh_next = pl.pallas_call(
        _kernel,
        grid=(n_blocks,),
        in_specs=[spec] * 6 + [pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((args[0].shape[0], d), z.dtype),
            jax.ShapeDtypeStruct((args[0].shape[0], d), z.dtype),
        ],
        interpret=True,
    )(*args, dt_arr)
    return z_next[:b], zh_next[:b]
