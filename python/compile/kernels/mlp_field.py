"""Layer-1 Pallas kernel: fused LipSwish-MLP vector-field evaluation.

The hot spot of a Neural SDE solve is evaluating the drift/diffusion MLPs
for every batch element at every step. On GPU the paper's torchsde
implementation leans on cuBLAS GEMMs with separate elementwise kernels; the
TPU-minded rethink (DESIGN.md §Hardware-Adaptation) is a single Pallas
kernel per MLP that

* tiles the **batch** dimension into VMEM-resident blocks (``BlockSpec``
  over axis 0), so a block's activations never round-trip to HBM between
  the two layers;
* feeds the MXU with the ``[block, in] @ [in, hidden]`` and
  ``[block, hidden] @ [hidden, out]`` GEMMs;
* fuses bias-add, LipSwish and the final nonlinearity into the same kernel.

Weights are small (``in, hidden, out ≤ 64`` here) and are broadcast to every
block (index map returns block 0), so the per-block VMEM working set is
``block·(in + hidden + out) + in·hidden + hidden·out`` floats — a few tens
of KiB, far below the ~16 MiB VMEM budget (see EXPERIMENTS.md §Perf for the
footprint table).

Lowering uses ``interpret=True`` — mandatory for CPU-PJRT execution; a real
TPU build would drop the flag and compile to Mosaic.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

#: Default batch block size. 128 rows keeps the MXU's 128-lane dimension
#: full while the per-block VMEM footprint stays ≪ 1 MiB. See the block
#: sweep in EXPERIMENTS.md §Perf.
DEFAULT_BLOCK = 128

_FINALS = ("none", "tanh", "sigmoid")


def _kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref, *, final):
    x = x_ref[...]
    # Layer 1 GEMM + bias + LipSwish, all in VMEM.
    h = jnp.dot(x, w1_ref[...]) + b1_ref[...][None, :]
    h = ref.LIPSWISH_SCALE * h * (1.0 / (1.0 + jnp.exp(-h)))
    # Layer 2 GEMM + bias + final nonlinearity.
    y = jnp.dot(h, w2_ref[...]) + b2_ref[...][None, :]
    if final == "tanh":
        y = jnp.tanh(y)
    elif final == "sigmoid":
        y = 1.0 / (1.0 + jnp.exp(-y))
    o_ref[...] = y


@functools.partial(jax.jit, static_argnames=("final", "block", "use_pallas"))
def mlp2_lipswish(x, w1, b1, w2, b2, final="none", block=DEFAULT_BLOCK,
                  use_pallas=True):
    """Fused two-layer LipSwish MLP.

    Semantics match :func:`compile.kernels.ref.mlp2_lipswish`. ``x`` is
    ``[B, in]``; the batch is padded up to a multiple of ``block`` (and
    un-padded on return) so any batch size works.
    """
    if final not in _FINALS:
        raise ValueError(f"final={final!r} not in {_FINALS}")
    if not use_pallas:
        return ref.mlp2_lipswish(x, w1, b1, w2, b2, final)
    b, d_in = x.shape
    d_h = w1.shape[1]
    d_out = w2.shape[1]
    blk = min(block, max(b, 1))
    pad = (-b) % blk
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad, d_in), x.dtype)], axis=0)
    n_blocks = x.shape[0] // blk
    out = pl.pallas_call(
        functools.partial(_kernel, final=final),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((blk, d_in), lambda i: (i, 0)),
            pl.BlockSpec((d_in, d_h), lambda i: (0, 0)),
            pl.BlockSpec((d_h,), lambda i: (0,)),
            pl.BlockSpec((d_h, d_out), lambda i: (0, 0)),
            pl.BlockSpec((d_out,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((blk, d_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((x.shape[0], d_out), x.dtype),
        interpret=True,
    )(x, w1, b1, w2, b2)
    return out[:b]


def vmem_footprint_bytes(block, d_in, d_h, d_out, dtype_bytes=4):
    """Estimated VMEM working set of one block invocation (for the perf
    analysis in EXPERIMENTS.md — interpret mode cannot measure this)."""
    acts = block * (d_in + d_h + d_out)
    weights = d_in * d_h + d_h + d_h * d_out + d_out
    return (acts + weights) * dtype_bytes


def mxu_utilisation_estimate(block, d_in, d_h, d_out):
    """Fraction of MXU (128×128 systolic array) lanes a block's GEMMs fill.

    Small vector-field MLPs underfill the contraction dimension; batching
    into 128-row blocks at least saturates the lane dimension. Returned as
    ``(layer1, layer2)`` estimates in [0, 1].
    """
    lane = min(block, 128) / 128.0
    return (lane * min(d_in, 128) / 128.0, lane * min(d_h, 128) / 128.0)
