"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here; pytest
(``python/tests/test_kernels.py``) asserts ``allclose`` between the two over
hypothesis-generated shapes and dtypes. The Layer-2 model can be switched
between kernels and oracles with ``use_pallas=False`` (the lowered artifacts
always use the kernels).
"""

import jax.numpy as jnp

#: LipSwish scale: ``ρ(x) = 0.909 · x · sigmoid(x)`` has Lipschitz constant
#: exactly 1 (Chen et al. 2019); the paper's Section-5 activation.
LIPSWISH_SCALE = 0.909


def sigmoid(x):
    """Numerically standard sigmoid."""
    return 1.0 / (1.0 + jnp.exp(-x))


def lipswish(x):
    """LipSwish activation (1-Lipschitz, smooth — paper Section 5)."""
    return LIPSWISH_SCALE * x * sigmoid(x)


def mlp2_lipswish(x, w1, b1, w2, b2, final="none"):
    """Two-layer MLP with LipSwish hidden activation.

    ``x: [B, in]``, ``w1: [in, h]``, ``b1: [h]``, ``w2: [h, out]``,
    ``b2: [out]``. ``final`` ∈ {"none", "tanh", "sigmoid"} is the output
    nonlinearity (the paper's σ_θ uses tanh to keep the diffusion bounded;
    the gradient-error test problem uses sigmoid finals).
    """
    h = lipswish(x @ w1 + b1)
    y = h @ w2 + b2
    if final == "tanh":
        y = jnp.tanh(y)
    elif final == "sigmoid":
        y = sigmoid(y)
    elif final != "none":
        raise ValueError(f"unknown final activation {final!r}")
    return y


def revheun_update(z, zh, mu, sdw, mu_next, sdw_next, dt):
    """Fused reversible-Heun state update (the linear part of Algorithm 1).

    Given the current state ``(z, ẑ)``, the cached field values applied to
    the step (``mu = μ_n``, ``sdw = σ_n·ΔW``) and the new field values
    (``mu_next = μ_{n+1}``, ``sdw_next = σ_{n+1}·ΔW``), produce
    ``(z_{n+1}, ẑ_{n+1})``:

    ``ẑ' = 2z − ẑ + μ_n Δt + σ_n ΔW``
    ``z' = z + ½(μ_n + μ_{n+1}) Δt + ½(σ_n ΔW + σ_{n+1} ΔW)``

    ``ẑ'`` is needed *before* the new fields can be evaluated, so the caller
    computes it first (same formula) — the kernel recomputes it internally
    rather than reading it from HBM, trading one FMA for a load. All tensors
    are ``[B, d]``; ``dt`` is a scalar.
    """
    zh_next = 2.0 * z - zh + mu * dt + sdw
    z_next = z + 0.5 * (mu + mu_next) * dt + 0.5 * (sdw + sdw_next)
    return z_next, zh_next


def batched_matvec(mat, vec):
    """``[B, e, d] @ [B, d] -> [B, e]`` — applying σ(t, X) to ΔW."""
    return jnp.einsum("bed,bd->be", mat, vec)
